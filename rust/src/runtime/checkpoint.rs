//! Versioned, CRC-validated, atomically-written checkpoint blobs
//! (DESIGN.md §15).
//!
//! # File layout
//!
//! ```text
//! magic  8 bytes  b"DPCKPT01"  (format name + container version)
//! len    u64 LE   payload length in bytes
//! payload         module-defined (trainer/multi-trainer state blob)
//! crc    u32 LE   CRC-32 (IEEE, reflected) over the payload
//! ```
//!
//! The container frames and validates; the *payload* carries its own
//! version word and fingerprint, written/read with [`ByteWriter`] /
//! [`ByteReader`] by `train::Trainer::state_blob` and friends. Writes go
//! to a temp file in the target directory followed by `rename`, so a
//! crash mid-write leaves either the previous checkpoint or a stray
//! `.tmp` file — never a truncated blob that a resume could half-read.

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Container magic: format + container version. Bump the trailing
/// digits on incompatible container changes; payload-level evolution
/// goes through the payload's own version word.
pub const MAGIC: &[u8; 8] = b"DPCKPT01";

/// CRC-32 (IEEE 802.3, reflected, init/xorout 0xFFFFFFFF) — the
/// ubiquitous zlib/PNG polynomial, table built on the fly (checkpoint
/// blobs are small enough that table construction is noise).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut table = [0u32; 256];
    for (i, slot) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
        }
        *slot = c;
    }
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

/// Write `contents` to `path` atomically *and durably*: temp file in
/// the same directory, `fsync` the data, `rename` into place, then
/// `fsync` the parent directory so the rename itself survives a host
/// crash. Readers never observe a partial file, and a checkpoint that
/// `try_resume` can see is actually on disk.
pub fn atomic_write(path: &Path, contents: &[u8]) -> Result<()> {
    use std::io::Write;

    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .with_context(|| format!("atomic write target {path:?} has no file name"))?;
    let tmp = path.with_file_name(format!(".{name}.tmp.{}", std::process::id()));
    let mut f =
        std::fs::File::create(&tmp).with_context(|| format!("creating temp file {tmp:?}"))?;
    f.write_all(contents)
        .with_context(|| format!("writing temp file {tmp:?}"))?;
    f.sync_all()
        .with_context(|| format!("fsyncing temp file {tmp:?}"))?;
    drop(f);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {tmp:?} into place at {path:?}"))?;
    // Without a directory fsync the rename lives only in the page
    // cache: a crash can resurrect the old file (or nothing) after
    // try_resume already reported the new one.
    #[cfg(unix)]
    {
        let parent = match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p,
            _ => Path::new("."),
        };
        std::fs::File::open(parent)
            .and_then(|d| d.sync_all())
            .with_context(|| format!("fsyncing parent directory {parent:?}"))?;
    }
    Ok(())
}

/// Atomically write a framed + CRC'd checkpoint blob.
pub fn save_atomic(path: &Path, payload: &[u8]) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating checkpoint directory {parent:?}"))?;
        }
    }
    let mut framed = Vec::with_capacity(payload.len() + 20);
    framed.extend_from_slice(MAGIC);
    framed.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    framed.extend_from_slice(payload);
    framed.extend_from_slice(&crc32(payload).to_le_bytes());
    atomic_write(path, &framed)
}

/// Read and validate a checkpoint file, returning the payload.
pub fn load(path: &Path) -> Result<Vec<u8>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading checkpoint {path:?}"))?;
    anyhow::ensure!(
        bytes.len() >= MAGIC.len() + 12,
        "checkpoint {path:?} is truncated ({} bytes)",
        bytes.len()
    );
    anyhow::ensure!(
        &bytes[..MAGIC.len()] == MAGIC,
        "checkpoint {path:?} has wrong magic (not a {} file, or an incompatible version)",
        String::from_utf8_lossy(MAGIC)
    );
    let mut len8 = [0u8; 8];
    len8.copy_from_slice(&bytes[8..16]);
    let len = u64::from_le_bytes(len8) as usize;
    anyhow::ensure!(
        bytes.len() == 16 + len + 4,
        "checkpoint {path:?} length mismatch: header says {len} payload bytes, file has {}",
        bytes.len().saturating_sub(20)
    );
    let payload = &bytes[16..16 + len];
    let mut crc4 = [0u8; 4];
    crc4.copy_from_slice(&bytes[16 + len..]);
    let want = u32::from_le_bytes(crc4);
    let got = crc32(payload);
    anyhow::ensure!(
        got == want,
        "checkpoint {path:?} failed CRC validation (stored {want:#010x}, computed {got:#010x})"
    );
    Ok(payload.to_vec())
}

/// Map a workload/graph name onto a safe checkpoint file stem.
pub fn sanitize_name(name: &str) -> String {
    let s: String = name
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.') {
                c
            } else {
                '-'
            }
        })
        .collect();
    if s.is_empty() {
        "unnamed".to_string()
    } else {
        s
    }
}

/// Checkpoint/resume configuration carried by `TrainConfig`.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointCfg {
    /// Directory the checkpoint blob lives in.
    pub dir: PathBuf,
    /// Write a checkpoint every N completed episodes (boundaries only:
    /// the batched Stage II path rounds up to its batch boundary).
    pub every: usize,
    /// Load the existing blob (if any) before training starts.
    pub resume: bool,
    /// Test/bench hook simulating a mid-run kill: force a checkpoint at
    /// the first boundary with >= N episodes done, then return a typed
    /// [`Interrupted`] error. The resume run must pass `None` here.
    pub halt_after: Option<usize>,
}

impl CheckpointCfg {
    pub fn new(dir: impl Into<PathBuf>) -> CheckpointCfg {
        CheckpointCfg {
            dir: dir.into(),
            every: 50,
            resume: false,
            halt_after: None,
        }
    }
}

/// Typed "simulated kill" error produced by `CheckpointCfg::halt_after`
/// after the forced checkpoint write; recoverable from `anyhow::Error`
/// via `downcast_ref::<Interrupted>()`.
#[derive(Clone, Debug)]
pub struct Interrupted {
    pub episodes_done: usize,
    pub path: PathBuf,
}

impl fmt::Display for Interrupted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "training halted after {} episodes (checkpoint written to {:?}; resume with --resume)",
            self.episodes_done, self.path
        )
    }
}

impl std::error::Error for Interrupted {}

// ---------------------------------------------------------------------------
// Little-endian payload serialization
// ---------------------------------------------------------------------------

/// Append-only little-endian payload builder.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u8(&mut self, x: u8) {
        self.buf.push(x);
    }
    pub fn put_u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    pub fn put_u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    pub fn put_usize(&mut self, x: usize) {
        self.put_u64(x as u64);
    }
    pub fn put_f32(&mut self, x: f32) {
        // bit pattern, not value: NaNs and -0.0 must round-trip exactly
        self.put_u32(x.to_bits());
    }
    pub fn put_f64(&mut self, x: f64) {
        self.put_u64(x.to_bits());
    }
    pub fn put_str(&mut self, s: &str) {
        self.put_usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }
    pub fn put_vec_f32(&mut self, xs: &[f32]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_f32(x);
        }
    }
    pub fn put_vec_usize(&mut self, xs: &[usize]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_usize(x);
        }
    }
    pub fn put_bytes(&mut self, bytes: &[u8]) {
        self.put_usize(bytes.len());
        self.buf.extend_from_slice(bytes);
    }
}

/// Cursor-based reader over a payload; every getter bounds-checks so a
/// corrupt blob produces an error, never a panic or a huge allocation.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.remaining(),
            "checkpoint payload truncated: need {n} bytes at offset {}, have {}",
            self.pos,
            self.remaining()
        );
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn get_u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        b.copy_from_slice(self.take(4)?);
        Ok(u32::from_le_bytes(b))
    }
    pub fn get_u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        b.copy_from_slice(self.take(8)?);
        Ok(u64::from_le_bytes(b))
    }
    pub fn get_usize(&mut self) -> Result<usize> {
        let x = self.get_u64()?;
        usize::try_from(x).map_err(|_| anyhow::anyhow!("checkpoint count {x} overflows usize"))
    }
    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.get_u32()?))
    }
    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }
    pub fn get_str(&mut self) -> Result<String> {
        let n = self.get_usize()?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).context("checkpoint string is not UTF-8")
    }
    pub fn get_vec_f32(&mut self) -> Result<Vec<f32>> {
        let n = self.get_usize()?;
        anyhow::ensure!(
            n.saturating_mul(4) <= self.remaining(),
            "checkpoint f32 vector length {n} exceeds remaining payload"
        );
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_f32()?);
        }
        Ok(out)
    }
    pub fn get_vec_usize(&mut self) -> Result<Vec<usize>> {
        let n = self.get_usize()?;
        anyhow::ensure!(
            n.saturating_mul(8) <= self.remaining(),
            "checkpoint usize vector length {n} exceeds remaining payload"
        );
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_usize()?);
        }
        Ok(out)
    }
    pub fn get_bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.get_usize()?;
        Ok(self.take(n)?.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "doppler-ckpt-test-{}-{tag}.bin",
            std::process::id()
        ))
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // Standard test vector for CRC-32/ISO-HDLC ("check" value).
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn byte_writer_reader_roundtrip() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 1);
        w.put_f32(f32::NAN);
        w.put_f32(-0.0);
        w.put_f64(std::f64::consts::PI);
        w.put_str("synthetic layered n=60");
        w.put_vec_f32(&[1.0, -2.5, 3.25]);
        w.put_vec_usize(&[0, 9, 18]);
        w.put_bytes(b"nested");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 1);
        // NaN round-trips by bit pattern
        assert_eq!(r.get_f32().unwrap().to_bits(), f32::NAN.to_bits());
        assert_eq!(r.get_f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.get_f64().unwrap(), std::f64::consts::PI);
        assert_eq!(r.get_str().unwrap(), "synthetic layered n=60");
        assert_eq!(r.get_vec_f32().unwrap(), vec![1.0, -2.5, 3.25]);
        assert_eq!(r.get_vec_usize().unwrap(), vec![0, 9, 18]);
        assert_eq!(r.get_bytes().unwrap(), b"nested");
        assert!(r.is_empty());
        // overrun is an error, not a panic
        assert!(r.get_u8().is_err());
    }

    #[test]
    fn save_load_roundtrip_and_crc_rejects_corruption() {
        let path = tmp_path("roundtrip");
        let payload: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        save_atomic(&path, &payload).unwrap();
        assert_eq!(load(&path).unwrap(), payload);

        // flip one payload byte → CRC failure mentioning the check
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[40] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("CRC"), "{err}");

        // wrong magic → clear error
        std::fs::write(&path, b"NOTMAGIC").unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("truncated") || err.contains("magic"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn atomic_write_replaces_and_leaves_no_temp() {
        let path = tmp_path("atomic");
        atomic_write(&path, b"first").unwrap();
        atomic_write(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        // no stray temp siblings with our prefix
        let dir = path.parent().unwrap();
        let stem = path.file_name().unwrap().to_str().unwrap().to_string();
        let strays = std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                let n = e.file_name().to_string_lossy().into_owned();
                n.starts_with(&format!(".{stem}.tmp"))
            })
            .count();
        assert_eq!(strays, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn atomic_write_durable_roundtrip_in_nested_dir() {
        // the fsync-temp + fsync-parent-dir path must still round-trip,
        // including in a freshly created nested directory
        let dir = std::env::temp_dir()
            .join(format!("doppler-ckpt-nested-{}", std::process::id()))
            .join("deep");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.bin");
        let payload: Vec<u8> = (0..=255u8).collect();
        atomic_write(&path, &payload).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), payload);
        atomic_write(&path, b"replaced").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"replaced");
        std::fs::remove_dir_all(dir.parent().unwrap()).ok();
    }

    #[test]
    fn sanitize_names() {
        assert_eq!(sanitize_name("synthetic layered n=60"), "synthetic-layered-n-60");
        assert_eq!(sanitize_name("llama-block"), "llama-block");
        assert_eq!(sanitize_name(""), "unnamed");
    }
}

//! Fault-tolerance primitives: retry/backoff policies, deterministic
//! failure injection, and the typed errors the resilient executors
//! surface (DESIGN.md §15).
//!
//! # Failure domains
//!
//! Every resilient execution point in the crate is a named *site*:
//!
//! | site              | covers                                             |
//! |-------------------|----------------------------------------------------|
//! | `rollout.map`     | generic `rollout::parallel_map` work items          |
//! | `rollout.sim`     | simulator replicates (`parallel_map_rng`)           |
//! | `rollout.episode` | whole-episode generation (`generate_episodes[_cfg]`)|
//! | `train.backward`  | per-episode backward passes in `train_batch`        |
//! | `engine.execute`  | Stage III real-engine reward collection             |
//! | `serve.policy`    | serving-ladder tier 2 policy inference attempts     |
//! | `serve.cache`     | serving-ladder tier 1 cache lookups (forced misses) |
//!
//! # Deterministic injection
//!
//! A [`FaultPlan`] (from `DOPPLER_FAULTS=...` or `--fault-plan ...`)
//! assigns failure rates to site prefixes. Whether attempt `a` of work
//! unit `u` fails is a pure function of
//! `(plan.seed, site, epoch, u, a)` — derived through the same
//! [`Rng::fork`] discipline as the rollout streams — where `epoch` is a
//! global counter bumped once per resilient-map invocation *on the
//! leader thread*. Worker count therefore never changes the failure
//! schedule: the same episodes fail at 1 thread and at 8, and a fault
//! run is reproducible end to end.
//!
//! # Retry-determinism contract
//!
//! A retried work item re-runs with a fresh clone of its *original*
//! forked RNG stream (`parallel_map_rng` clones `streams[i]` per
//! attempt), so an item that succeeds on attempt 3 is bit-identical to
//! one that succeeded on attempt 0, and the canonical-order merge is
//! unchanged. Consequently a fault-injected run whose retry budgets
//! survive produces bit-identical episodes and trained parameters to
//! the fault-free run. Injection draws are consumed per attempt, so a
//! rate < 1 lets retries succeed while rate = 1.0 deterministically
//! exhausts the budget (the typed-error path).

use std::any::Any;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::Duration;

use crate::util::rng::Rng;

/// Generic `rollout::parallel_map` work items.
pub const SITE_MAP: &str = "rollout.map";
/// Simulator replicates (`rollout::parallel_map_rng` / `mean_exec_time`).
pub const SITE_SIM: &str = "rollout.sim";
/// Whole-episode generation (`rollout::generate_episodes[_cfg]`).
pub const SITE_EPISODE: &str = "rollout.episode";
/// Per-episode backward passes in the accumulate-mode train batch.
pub const SITE_BACKWARD: &str = "train.backward";
/// Stage III real-engine reward collection.
pub const SITE_ENGINE: &str = "engine.execute";
/// Serving-ladder tier 2: policy inference per admitted request attempt.
pub const SITE_SERVE_POLICY: &str = "serve.policy";
/// Serving-ladder tier 1: assignment-cache lookups (an injected failure
/// is a forced miss, never an error — the ladder falls through).
pub const SITE_SERVE_CACHE: &str = "serve.cache";
/// Per-shard interior refinement in hierarchical placement
/// (`graph::partition::hierarchical_place`, DESIGN.md §17).
pub const SITE_PARTITION: &str = "partition.refine";

/// Default bounded retry budget when no [`FaultPlan`] is active: real
/// panics still get isolated and retried this many times before the
/// structured error surfaces.
pub const DEFAULT_MAX_ATTEMPTS: usize = 3;

/// Exponential backoff is capped here so an injected engine outage
/// cannot stall a run for minutes.
pub const MAX_BACKOFF_MS: u64 = 1_000;

/// FNV-1a over the site name: folds the site into the injection seed so
/// distinct sites draw from unrelated schedules.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// One injection rule: any site whose name starts with `site` fails
/// each attempt independently with probability `rate`.
#[derive(Clone, Debug, PartialEq)]
pub struct SiteRule {
    pub site: String,
    pub rate: f64,
}

/// A reproducible failure-injection configuration.
///
/// Spec grammar (comma-separated `key=value`):
/// `"rollout.sim=0.2,engine=1.0,seed=7,retries=4,backoff-ms=10,timeout-ms=500"`.
/// Reserved keys `seed` / `retries` / `backoff-ms` / `timeout-ms` set the
/// schedule seed and the [`RetryPolicy`]; every other key is a site
/// prefix with a failure rate in [0, 1]. First matching rule wins.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub rules: Vec<SiteRule>,
    pub max_attempts: usize,
    pub backoff_ms: u64,
    pub timeout_ms: Option<u64>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            rules: Vec::new(),
            max_attempts: DEFAULT_MAX_ATTEMPTS,
            backoff_ms: 0,
            timeout_ms: None,
        }
    }
}

impl FaultPlan {
    /// Parse the `DOPPLER_FAULTS` / `--fault-plan` spec string.
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((key, value)) = part.split_once('=') else {
                anyhow::bail!("fault-plan entry {part:?} is not key=value");
            };
            let (key, value) = (key.trim(), value.trim());
            match key {
                "seed" => {
                    plan.seed = value
                        .parse()
                        .map_err(|_| anyhow::anyhow!("fault-plan seed {value:?} is not a u64"))?;
                }
                "retries" => {
                    plan.max_attempts = value.parse().map_err(|_| {
                        anyhow::anyhow!("fault-plan retries {value:?} is not a count")
                    })?;
                    anyhow::ensure!(plan.max_attempts >= 1, "fault-plan retries must be >= 1");
                }
                "backoff-ms" => {
                    plan.backoff_ms = value.parse().map_err(|_| {
                        anyhow::anyhow!("fault-plan backoff-ms {value:?} is not a u64")
                    })?;
                }
                "timeout-ms" => {
                    plan.timeout_ms = Some(value.parse().map_err(|_| {
                        anyhow::anyhow!("fault-plan timeout-ms {value:?} is not a u64")
                    })?);
                }
                site => {
                    let rate: f64 = value.parse().map_err(|_| {
                        anyhow::anyhow!("fault-plan rate {value:?} for site {site:?} is not a number")
                    })?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&rate),
                        "fault-plan rate {rate} for site {site:?} must be in [0, 1]"
                    );
                    plan.rules.push(SiteRule {
                        site: site.to_string(),
                        rate,
                    });
                }
            }
        }
        Ok(plan)
    }

    /// Failure rate for a concrete site (first matching prefix rule).
    pub fn rate_for(&self, site: &str) -> f64 {
        self.rules
            .iter()
            .find(|r| site.starts_with(r.site.as_str()))
            .map_or(0.0, |r| r.rate)
    }

    /// Deterministic injection decision for `(site, epoch, unit, attempt)`.
    ///
    /// Pure in its arguments plus `self.seed`: the schedule is identical
    /// at any worker count and replayable across runs. Each attempt
    /// consumes one fresh draw from the per-(site, epoch, unit) stream.
    pub fn should_fail(&self, site: &str, epoch: u64, unit: u64, attempt: usize) -> bool {
        let rate = self.rate_for(site);
        if rate <= 0.0 {
            return false;
        }
        let mut root = Rng::new(self.seed ^ fnv1a(site));
        let mut per_epoch = root.fork(epoch);
        let mut per_unit = per_epoch.fork(unit);
        for _ in 0..attempt {
            per_unit.f64();
        }
        per_unit.chance(rate)
    }
}

/// Retry/timeout/backoff knobs shared by the rollout executor and the
/// engine wrapper. Detached from [`FaultPlan`] so callers can retry real
/// (non-injected) failures with the defaults when no plan is active.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    pub max_attempts: usize,
    pub backoff_ms: u64,
    pub timeout_ms: Option<u64>,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: DEFAULT_MAX_ATTEMPTS,
            backoff_ms: 0,
            timeout_ms: None,
        }
    }
}

impl RetryPolicy {
    /// Policy in effect for an optional active plan.
    pub fn from_plan(plan: Option<&FaultPlan>) -> RetryPolicy {
        plan.map_or_else(RetryPolicy::default, |p| RetryPolicy {
            max_attempts: p.max_attempts.max(1),
            backoff_ms: p.backoff_ms,
            timeout_ms: p.timeout_ms,
        })
    }

    /// Exponential backoff for the given attempt index, capped at
    /// [`MAX_BACKOFF_MS`]. Zero base → no sleep (the rollout executor
    /// never sleeps: retried items are pure compute).
    pub fn backoff(&self, attempt: usize) -> Duration {
        if self.backoff_ms == 0 {
            return Duration::ZERO;
        }
        let factor = 1u64.checked_shl(attempt.min(63) as u32).unwrap_or(u64::MAX);
        Duration::from_millis(self.backoff_ms.saturating_mul(factor).min(MAX_BACKOFF_MS))
    }

    /// Sleep out the backoff for `attempt` (no-op for zero durations).
    pub fn backoff_sleep(&self, attempt: usize) {
        let d = self.backoff(attempt);
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }
}

// ---------------------------------------------------------------------------
// Global plan + epoch + counters
// ---------------------------------------------------------------------------

fn plan_cell() -> &'static RwLock<Option<Arc<FaultPlan>>> {
    static CELL: OnceLock<RwLock<Option<Arc<FaultPlan>>>> = OnceLock::new();
    CELL.get_or_init(|| RwLock::new(plan_from_env()))
}

fn plan_from_env() -> Option<Arc<FaultPlan>> {
    let spec = std::env::var("DOPPLER_FAULTS").ok()?;
    if spec.is_empty() {
        return None;
    }
    match FaultPlan::parse(&spec) {
        Ok(p) => Some(Arc::new(p)),
        Err(e) => {
            eprintln!("warning: ignoring DOPPLER_FAULTS={spec:?}: {e:#}");
            None
        }
    }
}

/// Install (or clear, with `None`) the process-wide fault plan,
/// resetting the injection epoch so a fresh run replays the same
/// schedule. Overrides any `DOPPLER_FAULTS` initialization.
pub fn set_plan(plan: Option<Arc<FaultPlan>>) {
    let mut cell = plan_cell().write().unwrap_or_else(|e| e.into_inner());
    *cell = plan;
    EPOCH.store(0, Ordering::SeqCst);
}

/// The currently active fault plan, if any.
pub fn active_plan() -> Option<Arc<FaultPlan>> {
    plan_cell().read().unwrap_or_else(|e| e.into_inner()).clone()
}

/// True when failure injection is enabled.
pub fn plan_active() -> bool {
    active_plan().is_some()
}

static EPOCH: AtomicU64 = AtomicU64::new(0);

/// Claim the next injection epoch. Called once per resilient-map
/// invocation on the leader thread (i.e. serialized by construction),
/// which keys the failure schedule independently of worker count. Only
/// bumped while a plan is active, so fault-free runs share no state.
pub fn next_epoch() -> u64 {
    EPOCH.fetch_add(1, Ordering::SeqCst)
}

/// Process-wide fault-handling event counters (monotonic; reset with
/// [`reset_stats`]). Reported by the CLI after fault-injected runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Injected (synthetic) failures from the active plan.
    pub injected: u64,
    /// Real panics caught at a work-item boundary.
    pub panics: u64,
    /// Work items that failed at least once and then succeeded.
    pub retried_ok: u64,
    /// Work items that exhausted their retry budget.
    pub exhausted: u64,
    /// Non-finite rewards/losses/gradients quarantined before Adam.
    pub anomalies: u64,
    /// Stage III episodes that fell back to simulator rewards.
    pub engine_fallbacks: u64,
}

impl fmt::Display for FaultStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "injected={} panics={} retried_ok={} exhausted={} anomalies={} engine_fallbacks={}",
            self.injected,
            self.panics,
            self.retried_ok,
            self.exhausted,
            self.anomalies,
            self.engine_fallbacks
        )
    }
}

static INJECTED: AtomicU64 = AtomicU64::new(0);
static PANICS: AtomicU64 = AtomicU64::new(0);
static RETRIED_OK: AtomicU64 = AtomicU64::new(0);
static EXHAUSTED: AtomicU64 = AtomicU64::new(0);
static ANOMALIES: AtomicU64 = AtomicU64::new(0);
static ENGINE_FALLBACKS: AtomicU64 = AtomicU64::new(0);

/// Snapshot the process-wide counters.
pub fn stats() -> FaultStats {
    FaultStats {
        injected: INJECTED.load(Ordering::Relaxed),
        panics: PANICS.load(Ordering::Relaxed),
        retried_ok: RETRIED_OK.load(Ordering::Relaxed),
        exhausted: EXHAUSTED.load(Ordering::Relaxed),
        anomalies: ANOMALIES.load(Ordering::Relaxed),
        engine_fallbacks: ENGINE_FALLBACKS.load(Ordering::Relaxed),
    }
}

/// Zero all counters (test isolation / per-run reporting).
pub fn reset_stats() {
    INJECTED.store(0, Ordering::Relaxed);
    PANICS.store(0, Ordering::Relaxed);
    RETRIED_OK.store(0, Ordering::Relaxed);
    EXHAUSTED.store(0, Ordering::Relaxed);
    ANOMALIES.store(0, Ordering::Relaxed);
    ENGINE_FALLBACKS.store(0, Ordering::Relaxed);
}

pub fn count_injected() {
    INJECTED.fetch_add(1, Ordering::Relaxed);
}
pub fn count_panic() {
    PANICS.fetch_add(1, Ordering::Relaxed);
}
pub fn count_retry_ok() {
    RETRIED_OK.fetch_add(1, Ordering::Relaxed);
}
pub fn count_exhausted() {
    EXHAUSTED.fetch_add(1, Ordering::Relaxed);
}
/// A non-finite reward/loss/gradient was quarantined (skip-and-count).
pub fn note_anomaly() {
    ANOMALIES.fetch_add(1, Ordering::Relaxed);
}
pub fn count_engine_fallback() {
    ENGINE_FALLBACKS.fetch_add(1, Ordering::Relaxed);
}

/// Render a `catch_unwind` payload as a human-readable message.
pub fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked with a non-string payload".to_string()
    }
}

// ---------------------------------------------------------------------------
// Typed errors
// ---------------------------------------------------------------------------

/// One work item that exhausted its retry budget.
#[derive(Clone, Debug)]
pub struct ItemFailure {
    /// Canonical work-unit index (episode·reps + replicate, etc.).
    pub index: usize,
    /// Attempts consumed (== the budget when exhausted).
    pub attempts: usize,
    /// How many of those attempts were injected (vs real panics).
    pub injected: usize,
    /// Message from the last failed attempt.
    pub last_error: String,
}

/// Structured failure of a resilient rollout map: which site, how many
/// items failed out of how many, and per-item attempt counts. Replaces
/// the old `expect("rollout worker panicked")` hard abort.
#[derive(Clone, Debug)]
pub struct RolloutError {
    pub site: &'static str,
    /// Total work items in the failed map invocation.
    pub total: usize,
    /// Items that exhausted their budget, in canonical index order.
    pub failures: Vec<ItemFailure>,
}

impl fmt::Display for RolloutError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "rollout site '{}': {}/{} work items failed",
            self.site,
            self.failures.len(),
            self.total
        )?;
        for fl in self.failures.iter().take(3) {
            write!(
                f,
                "; item {} after {} attempts ({} injected): {}",
                fl.index, fl.attempts, fl.injected, fl.last_error
            )?;
        }
        if self.failures.len() > 3 {
            write!(f, "; ... and {} more", self.failures.len() - 3)?;
        }
        Ok(())
    }
}

impl std::error::Error for RolloutError {}

/// The real engine stayed unavailable through the whole retry budget
/// (Stage III). The trainer degrades to simulator rewards on this.
#[derive(Clone, Debug)]
pub struct EngineUnavailable {
    pub episode: u64,
    pub attempts: usize,
    pub last_error: String,
}

impl fmt::Display for EngineUnavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "engine unavailable for episode {} after {} attempts: {}",
            self.episode, self.attempts, self.last_error
        )
    }
}

impl std::error::Error for EngineUnavailable {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse("rollout.sim=0.25, engine=1.0, seed=9, retries=4, backoff-ms=10, timeout-ms=500")
            .unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.max_attempts, 4);
        assert_eq!(p.backoff_ms, 10);
        assert_eq!(p.timeout_ms, Some(500));
        assert_eq!(p.rules.len(), 2);
        assert_eq!(p.rate_for("rollout.sim"), 0.25);
        assert_eq!(p.rate_for("engine.execute"), 1.0);
        assert_eq!(p.rate_for("rollout.episode"), 0.0);
        assert_eq!(p.rate_for("train.backward"), 0.0);
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(FaultPlan::parse("rollout.sim").is_err());
        assert!(FaultPlan::parse("rollout.sim=2.0").is_err());
        assert!(FaultPlan::parse("rollout.sim=-0.1").is_err());
        assert!(FaultPlan::parse("seed=abc").is_err());
        assert!(FaultPlan::parse("retries=0").is_err());
        // empty / whitespace specs are a valid no-rule plan
        let p = FaultPlan::parse("").unwrap();
        assert!(p.rules.is_empty());
    }

    #[test]
    fn prefix_rule_covers_all_rollout_sites() {
        let p = FaultPlan::parse("rollout=0.5").unwrap();
        assert_eq!(p.rate_for(SITE_MAP), 0.5);
        assert_eq!(p.rate_for(SITE_SIM), 0.5);
        assert_eq!(p.rate_for(SITE_EPISODE), 0.5);
        assert_eq!(p.rate_for(SITE_BACKWARD), 0.0);
    }

    #[test]
    fn should_fail_is_pure_and_attempt_sensitive() {
        let mut p = FaultPlan::parse("rollout.sim=0.5,seed=3").unwrap();
        // pure: same arguments, same verdict — at any call count
        for _ in 0..3 {
            assert_eq!(
                p.should_fail(SITE_SIM, 2, 7, 0),
                p.should_fail(SITE_SIM, 2, 7, 0)
            );
        }
        // the schedule varies across epochs/units/attempts: at rate 0.5
        // over 64 cells, both outcomes must occur
        let mut saw = [false; 2];
        for unit in 0..64u64 {
            saw[p.should_fail(SITE_SIM, 0, unit, 0) as usize] = true;
        }
        assert!(saw[0] && saw[1], "rate-0.5 schedule is degenerate");
        // a failed attempt can succeed on retry (fresh draw per attempt)
        let failing_unit = (0..64u64)
            .find(|&u| p.should_fail(SITE_SIM, 0, u, 0))
            .unwrap();
        assert!(
            (1..16).any(|a| !p.should_fail(SITE_SIM, 0, failing_unit, a)),
            "no retry ever succeeds at rate 0.5"
        );
        // the seed changes the schedule
        let q = FaultPlan::parse("rollout.sim=0.5,seed=4").unwrap();
        assert!(
            (0..64u64).any(|u| q.should_fail(SITE_SIM, 0, u, 0) != p.should_fail(SITE_SIM, 0, u, 0)),
            "seed 3 and seed 4 produced identical 64-unit schedules"
        );
        // rate 1.0 fails every attempt (guaranteed budget exhaustion)
        p.rules[0].rate = 1.0;
        assert!((0..8).all(|a| p.should_fail(SITE_SIM, 0, failing_unit, a)));
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let r = RetryPolicy {
            max_attempts: 8,
            backoff_ms: 10,
            timeout_ms: None,
        };
        assert_eq!(r.backoff(0), Duration::from_millis(10));
        assert_eq!(r.backoff(1), Duration::from_millis(20));
        assert_eq!(r.backoff(3), Duration::from_millis(80));
        assert_eq!(r.backoff(20), Duration::from_millis(MAX_BACKOFF_MS));
        assert_eq!(r.backoff(200), Duration::from_millis(MAX_BACKOFF_MS));
        let none = RetryPolicy::default();
        assert_eq!(none.backoff(5), Duration::ZERO);
    }

    #[test]
    fn rollout_error_display_lists_items() {
        let e = RolloutError {
            site: SITE_SIM,
            total: 8,
            failures: vec![ItemFailure {
                index: 3,
                attempts: 3,
                injected: 3,
                last_error: "injected fault (attempt 2)".into(),
            }],
        };
        let s = e.to_string();
        assert!(s.contains("rollout.sim"), "{s}");
        assert!(s.contains("1/8"), "{s}");
        assert!(s.contains("item 3 after 3 attempts"), "{s}");
    }

    // Global-state tests use a site prefix that matches no real site, so
    // concurrently running lib tests can never observe an injection.
    #[test]
    fn plan_cell_roundtrip() {
        let plan = Arc::new(FaultPlan::parse("test.nowhere=1.0,seed=5").unwrap());
        set_plan(Some(plan.clone()));
        let got = active_plan().expect("plan should be active");
        assert_eq!(*got, *plan);
        assert!(plan_active());
        set_plan(None);
        // NOTE: cannot assert !plan_active() here — another test thread
        // may have installed its own plan in the meantime. The property
        // tests in tests/resilience.rs serialize on a mutex instead.
    }
}

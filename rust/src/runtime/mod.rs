//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them on the CPU PJRT client.
//! This is the only place rust touches XLA; Python never runs here.
//!
//! Interchange is HLO *text* — `HloModuleProto::from_text_file` reassigns
//! instruction ids, avoiding the 64-bit-id protos of jax >= 0.5 that
//! xla_extension 0.5.1 rejects (see /opt/xla-example/README.md).

pub mod checkpoint;
pub mod manifest;
pub mod resilience;

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

pub use manifest::Manifest;

/// Shared PJRT CPU client + artifact loader.
pub struct Runtime {
    client: PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn new() -> Result<Runtime> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Load and compile one HLO-text artifact.
    pub fn load(&self, path: &std::path::Path) -> Result<Executable> {
        let name = artifact_name(path)?;
        let text = path
            .to_str()
            .with_context(|| format!("artifact path {path:?} is not valid UTF-8"))?;
        let proto = HloModuleProto::from_text_file(text)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable { exe, name })
    }
}

/// Display name of an artifact path: its final component. Paths without
/// one (`.`, `..`, `/`, empty) are manifest/CLI mistakes — report them
/// instead of panicking.
pub fn artifact_name(path: &std::path::Path) -> Result<String> {
    let name = path
        .file_name()
        .with_context(|| format!("artifact path {path:?} has no file name component"))?;
    Ok(name.to_string_lossy().into_owned())
}

/// A compiled policy-network executable.
pub struct Executable {
    exe: PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with host literals; returns the decomposed output tuple
    /// (aot.py lowers everything with `return_tuple=True`).
    pub fn run(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        let out = self
            .exe
            .execute::<Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        lit.to_tuple()
            .with_context(|| format!("untupling result of {}", self.name))
    }

    /// Like [`Executable::run`] but borrowing the literals — lets hot
    /// loops reuse episode-constant argument literals (params, Hcat)
    /// instead of re-marshalling them every call (§Perf L3).
    pub fn run_refs(&self, args: &[&Literal]) -> Result<Vec<Literal>> {
        let out = self
            .exe
            .execute::<&Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        lit.to_tuple()
            .with_context(|| format!("untupling result of {}", self.name))
    }
}

/// Literal construction/extraction helpers for the f32/i32 tensors the
/// policy executables exchange.
pub mod lit {
    use super::*;

    /// f32 tensor from a flat slice + dims.
    pub fn f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
        Ok(Literal::vec1(data).reshape(dims)?)
    }

    /// i32 tensor from a flat slice + dims.
    pub fn i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
        Ok(Literal::vec1(data).reshape(dims)?)
    }

    /// 1-element f32 tensor (the `[1]`-shaped scalars of the train step).
    pub fn scalar1(x: f32) -> Result<Literal> {
        f32(&[x], &[1])
    }

    /// Extract a flat f32 vector.
    pub fn to_f32(l: &Literal) -> Result<Vec<f32>> {
        Ok(l.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::artifact_name;
    use std::path::Path;

    #[test]
    fn artifact_name_takes_final_component() {
        assert_eq!(artifact_name(Path::new("artifacts/encode.hlo.txt")).unwrap(), "encode.hlo.txt");
        assert_eq!(artifact_name(Path::new("plain.txt")).unwrap(), "plain.txt");
    }

    #[test]
    fn artifact_name_rejects_nameless_paths() {
        for bad in [".", "..", "/", "artifacts/.."] {
            let err = artifact_name(Path::new(bad)).unwrap_err();
            assert!(
                err.to_string().contains("no file name"),
                "{bad}: unexpected error {err}"
            );
        }
    }
}

//! Small statistics toolkit used by the evaluation harness: mean/std
//! summaries for the paper-style `a ± b` cells, and Pearson/Spearman
//! correlation for the Fig. 26 simulator-fidelity study.

/// Mean of a sample (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0.0 when n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let ss = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>();
    (ss / (xs.len() - 1) as f64).sqrt()
}

/// Minimum (infinity for empty input).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum (-infinity for empty input).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Percentile via linear interpolation, p in [0, 100]. NaN measurements
/// are dropped before ranking so one poisoned sample cannot panic the
/// bench harness (0.0 when nothing comparable remains).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Pearson product-moment correlation. NaN-free: pairs with a
/// non-finite coordinate are dropped, and 0.0 is returned when either
/// variable is constant or fewer than two comparable pairs remain.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let pairs = finite_pairs(xs, ys);
    let n = pairs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = pairs.iter().map(|p| p.0).sum::<f64>() / n as f64;
    let my = pairs.iter().map(|p| p.1).sum::<f64>() / n as f64;
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in pairs {
        let a = x - mx;
        let b = y - my;
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx == 0.0 || dy == 0.0 {
        return 0.0;
    }
    num / (dx.sqrt() * dy.sqrt())
}

/// Pairs where both coordinates are finite (the only ones a correlation
/// can rank meaningfully).
fn finite_pairs(xs: &[f64], ys: &[f64]) -> Vec<(f64, f64)> {
    xs.iter()
        .zip(ys)
        .map(|(&x, &y)| (x, y))
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect()
}

/// Fractional ranks with ties sharing their average rank.
fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut r = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

/// Spearman rank correlation (Pearson over tie-averaged ranks).
/// Non-finite pairs are dropped *before* ranking so a NaN measurement
/// neither panics nor distorts the ranks of the comparable samples.
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let pairs = finite_pairs(xs, ys);
    let fx: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let fy: Vec<f64> = pairs.iter().map(|p| p.1).collect();
    pearson(&ranks(&fx), &ranks(&fy))
}

/// A `mean ± std` summary of repeated measurements.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub mean: f64,
    pub std: f64,
    pub n: usize,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            mean: mean(xs),
            std: std_dev(xs),
            n: xs.len(),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.1} ± {:.1}", self.mean, self.std)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn pearson_perfect_linear() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg = [-2.0, -4.0, -6.0, -8.0];
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        let xs = [1.0, 1.0, 1.0];
        let ys = [1.0, 2.0, 3.0];
        assert_eq!(pearson(&xs, &ys), 0.0);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let ys = [1.0, 8.0, 27.0, 64.0, 125.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_handles_ties() {
        let xs = [1.0, 2.0, 2.0, 3.0];
        let ys = [1.0, 2.0, 2.0, 3.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_ignores_nan_samples() {
        // one poisoned measurement must neither panic nor shift ranks
        let xs = [3.0, f64::NAN, 1.0, 2.0, 4.0];
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 50.0), 0.0);
    }

    #[test]
    fn percentile_keeps_infinities_ordered() {
        let xs = [f64::INFINITY, 1.0, f64::NEG_INFINITY];
        assert_eq!(percentile(&xs, 0.0), f64::NEG_INFINITY);
        assert_eq!(percentile(&xs, 50.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), f64::INFINITY);
    }

    #[test]
    fn pearson_drops_nonfinite_pairs() {
        // dropping the poisoned pair leaves a perfect linear relation
        let xs = [1.0, 2.0, f64::NAN, 4.0];
        let ys = [2.0, 4.0, 9.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        // all pairs poisoned: defined 0.0, never NaN
        let bad = [f64::NAN, f64::INFINITY];
        assert_eq!(pearson(&bad, &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn spearman_nan_input_is_finite() {
        let xs = [1.0, f64::NAN, 3.0, 4.0, 5.0];
        let ys = [1.0, 8.0, 27.0, f64::NAN, 125.0];
        let r = spearman(&xs, &ys);
        assert!(r.is_finite());
        assert!((r - 1.0).abs() < 1e-12, "monotone on comparable pairs: {r}");
    }

    #[test]
    fn summary_display() {
        let s = Summary::of(&[10.0, 12.0, 14.0]);
        assert_eq!(format!("{s}"), "12.0 ± 2.0");
    }
}

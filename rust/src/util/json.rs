//! Minimal JSON support (the offline image has no serde): a value model,
//! a recursive-descent parser, and a writer. Used for the artifacts
//! manifest emitted by `python/compile/aot.py` and for run-log records.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (the manifest only stores sizes
/// and offsets well below 2^53).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building log records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }
    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let start = self.i;
                    let text = std::str::from_utf8(&self.b[start..]).map_err(|_| "bad utf8")?;
                    let ch = text.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let is_num_byte =
            |c: u8| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-');
        while matches!(self.peek(), Some(c) if is_num_byte(c)) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let text = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": 2.5}}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("a").as_usize(), Some(1));
        assert_eq!(v.get("b").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").get("d").as_f64(), Some(2.5));
        // serialize → parse → same value
        let again = parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn parses_negative_and_exponent() {
        let v = parse("[-1.5e3, 0, 42]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1500.0));
        assert_eq!(a[2].as_usize(), Some(42));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nope").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Ab""#).unwrap();
        assert_eq!(v.as_str(), Some("Ab"));
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
    }
}

//! Deterministic PRNG (xoshiro256++) — the offline image has no `rand`
//! crate, and we want reproducible experiments anyway: every stochastic
//! component (simulator jitter, ε-greedy, softmax sampling, tie-breaking)
//! takes an explicit `Rng` seeded from the experiment config.

/// xoshiro256++ by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// splitmix64 — used to expand a single u64 seed into xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for parallel workers).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the raw generator state (for checkpoint serialization).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot. The restored
    /// generator continues the exact output sequence of the original.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 top bits → [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform usize in [0, n). `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free bound (bias negligible for our n).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with median 1 and shape sigma: exp(sigma * N(0,1)).
    pub fn lognormal(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Falls back to uniform if the total mass is not positive/finite.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().copied().filter(|w| w.is_finite()).sum();
        if !(total > 0.0) {
            return self.below(weights.len());
        }
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            if w.is_finite() && w > 0.0 {
                u -= w;
                if u <= 0.0 {
                    return i;
                }
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_prefers_heavy_arm() {
        let mut r = Rng::new(13);
        let w = [0.0, 1.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[2] > counts[1] * 5);
    }

    #[test]
    fn weighted_degenerate_total_uniform() {
        let mut r = Rng::new(17);
        let w = [0.0, 0.0];
        let mut seen = [false; 2];
        for _ in 0..100 {
            seen[r.weighted(&w)] = true;
        }
        assert!(seen[0] && seen[1]);
    }

    /// Same parent state + same stream key => the forked child reproduces
    /// exactly. This is the root of the parallel-rollout determinism
    /// contract (rollout workers replay leader-forked streams).
    #[test]
    fn fork_same_stream_reproduces() {
        for stream in [0u64, 1, 7, u64::MAX] {
            let mut a = Rng::new(1234);
            let mut b = Rng::new(1234);
            let mut ca = a.fork(stream);
            let mut cb = b.fork(stream);
            for _ in 0..200 {
                assert_eq!(ca.next_u64(), cb.next_u64(), "stream {stream}");
            }
        }
    }

    /// Forking advances the parent deterministically: after k forks, two
    /// equal parents remain equal (so leaders that fork a batch of
    /// streams stay replayable).
    #[test]
    fn fork_advances_parent_deterministically() {
        let mut a = Rng::new(77);
        let mut b = Rng::new(77);
        for i in 0..16u64 {
            let _ = a.fork(i);
            let _ = b.fork(i);
        }
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    /// Distinct streams must be independent: no raw-output collisions to
    /// speak of, and no lockstep correlation between the streams'
    /// uniform deviates.
    #[test]
    fn fork_distinct_streams_do_not_correlate() {
        let mut parent = Rng::new(42);
        // Note: sibling forks also differ because the parent state
        // advances per fork; the stream key separates forks taken from
        // identical parent states (as parallel_map_rng relies on).
        let mut children: Vec<Rng> = (0..8u64).map(|s| parent.fork(s)).collect();
        let n = 4096;
        let seqs: Vec<Vec<u64>> = children
            .iter_mut()
            .map(|c| (0..n).map(|_| c.next_u64()).collect())
            .collect();
        for i in 0..seqs.len() {
            for j in (i + 1)..seqs.len() {
                let equal = seqs[i]
                    .iter()
                    .zip(&seqs[j])
                    .filter(|(x, y)| x == y)
                    .count();
                assert!(equal <= 1, "streams {i},{j}: {equal}/{n} identical outputs");
                // lagged self-similarity: the pairwise XOR popcount of
                // uniform u64s concentrates hard around 32
                let mean_pop: f64 = seqs[i]
                    .iter()
                    .zip(&seqs[j])
                    .map(|(x, y)| (x ^ y).count_ones() as f64)
                    .sum::<f64>()
                    / n as f64;
                assert!(
                    (mean_pop - 32.0).abs() < 1.0,
                    "streams {i},{j}: mean xor popcount {mean_pop}"
                );
            }
        }
    }

    /// Identical parent states forked with different stream keys must
    /// still diverge — the key alone has to separate work units, since
    /// parallel_map_rng derives unit i's stream from key i.
    #[test]
    fn fork_stream_key_separates_identical_parents() {
        let parent = Rng::new(9);
        let mut c0 = parent.clone().fork(0);
        let mut c1 = parent.clone().fork(1);
        let same = (0..256).filter(|_| c0.next_u64() == c1.next_u64()).count();
        assert!(same <= 1, "{same}/256 collisions between stream 0 and 1");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    /// state()/from_state() must round-trip mid-sequence: a restored
    /// generator continues bit-for-bit where the snapshot was taken
    /// (the checkpoint/resume contract, DESIGN.md §15).
    #[test]
    fn state_snapshot_roundtrips_mid_sequence() {
        let mut r = Rng::new(0xC0FFEE);
        for _ in 0..37 {
            r.next_u64();
        }
        let snap = r.state();
        let mut restored = Rng::from_state(snap);
        for i in 0..256 {
            assert_eq!(r.next_u64(), restored.next_u64(), "diverged at output {i}");
        }
        // forks from the restored generator match too
        let mut r2 = Rng::from_state(r.state());
        let mut a = r.fork(5);
        let mut b = r2.fork(5);
        assert_eq!(a.next_u64(), b.next_u64());
    }
}

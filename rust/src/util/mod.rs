//! Shared utilities: deterministic PRNG, statistics, minimal JSON.
//! (The offline image ships no rand/serde/criterion — see DESIGN.md §8.)

pub mod json;
pub mod rng;
pub mod stats;

/// Format a milliseconds quantity the way the paper's tables do.
pub fn fmt_ms(x: f64) -> String {
    format!("{x:.1}")
}

/// Read an env var as usize with a default (used for episode budgets).
/// A set-but-unparseable value falls back to the default with a one-line
/// stderr warning (silent fallback hid typos like `DOPPLER_EPISODES=4OO`).
pub fn env_usize(name: &str, default: usize) -> usize {
    env_parsed(name, default)
}

/// Read an env var as f64 with a default (same warning contract).
pub fn env_f64(name: &str, default: f64) -> f64 {
    env_parsed(name, default)
}

/// Shared impl: unset or empty → default silently; set-but-unparseable →
/// default with a warning naming the variable and the rejected value.
fn env_parsed<T>(name: &str, default: T) -> T
where
    T: std::str::FromStr + std::fmt::Display + Copy,
{
    match std::env::var(name) {
        Err(_) => default,
        Ok(v) if v.is_empty() => default,
        Ok(v) => match v.parse() {
            Ok(x) => x,
            Err(_) => {
                eprintln!(
                    "warning: ignoring {name}={v:?}: expected a number; using default {default}"
                );
                default
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Each test uses a unique variable name: the test harness runs tests
    // on parallel threads sharing one process environment.

    #[test]
    fn env_usize_parses_set_values() {
        std::env::set_var("DOPPLER_TEST_ENV_USIZE_OK", "42");
        assert_eq!(env_usize("DOPPLER_TEST_ENV_USIZE_OK", 7), 42);
        std::env::remove_var("DOPPLER_TEST_ENV_USIZE_OK");
    }

    #[test]
    fn env_usize_unset_and_empty_fall_back_silently() {
        assert_eq!(env_usize("DOPPLER_TEST_ENV_USIZE_UNSET", 7), 7);
        std::env::set_var("DOPPLER_TEST_ENV_USIZE_EMPTY", "");
        assert_eq!(env_usize("DOPPLER_TEST_ENV_USIZE_EMPTY", 9), 9);
        std::env::remove_var("DOPPLER_TEST_ENV_USIZE_EMPTY");
    }

    #[test]
    fn env_usize_rejects_garbage_with_default() {
        std::env::set_var("DOPPLER_TEST_ENV_USIZE_BAD", "4OO");
        // warns on stderr (not capturable here) and keeps the default
        assert_eq!(env_usize("DOPPLER_TEST_ENV_USIZE_BAD", 11), 11);
        std::env::remove_var("DOPPLER_TEST_ENV_USIZE_BAD");
    }

    #[test]
    fn env_f64_rejects_garbage_with_default() {
        std::env::set_var("DOPPLER_TEST_ENV_F64_BAD", "fast");
        assert_eq!(env_f64("DOPPLER_TEST_ENV_F64_BAD", 0.5), 0.5);
        std::env::remove_var("DOPPLER_TEST_ENV_F64_BAD");
        std::env::set_var("DOPPLER_TEST_ENV_F64_OK", "2.5");
        assert_eq!(env_f64("DOPPLER_TEST_ENV_F64_OK", 0.5), 2.5);
        std::env::remove_var("DOPPLER_TEST_ENV_F64_OK");
    }
}

//! Shared utilities: deterministic PRNG, statistics, minimal JSON.
//! (The offline image ships no rand/serde/criterion — see DESIGN.md §8.)

pub mod json;
pub mod rng;
pub mod stats;

/// Format a milliseconds quantity the way the paper's tables do.
pub fn fmt_ms(x: f64) -> String {
    format!("{x:.1}")
}

/// Read an env var as usize with a default (used for episode budgets).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Read an env var as f64 with a default.
pub fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

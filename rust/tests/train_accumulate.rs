//! Accumulate-mode determinism pins (ISSUE 5 / DESIGN.md §13).
//!
//! `--update-mode accumulate` applies ONE clipped Adam step per episode
//! batch, with per-episode gradients computed in parallel from one
//! parameter snapshot and reduced by IEEE total order. Its contract:
//!
//! - **thread counts never leak** — trained params are bit-identical at
//!   1/2/4/8 rollout threads;
//! - **within-batch episode order never leaks** — permuting the items
//!   handed to `train_batch` permutes the returned stats but leaves the
//!   updated `params`/`opt` bit-identical (the gradient reduction is a
//!   pure function of the multiset of per-episode gradients);
//! - **a single-item batch is exactly one sequential step** — the
//!   reduction degenerates to the identity and the same clipped Adam
//!   tail runs, so `episode_batch = 1` accumulate training reproduces
//!   sequential training bit for bit;
//! - **larger batches are intentionally different numerics** — one
//!   optimizer step per batch, `opt.t` counting batches.
//!
//! Runs entirely on the native backend: zero artifacts required. CI
//! runs this file as a named step in the determinism-pins job.

use doppler::graph::workloads::{chainmm, Scale};
use doppler::policy::{
    device_mask, EpisodeCfg, GraphEncoding, Method, NativePolicy, OptState, PolicyBackend,
    TrainItem,
};
use doppler::sim::topology::DeviceTopology;
use doppler::train::{Schedule, TrainConfig, UpdateMode};
use doppler::util::rng::Rng;

/// Small accumulate-mode Stage II run; returns (params, history pairs).
fn run_stage2(threads: usize, batch: usize, mode: UpdateMode) -> (Vec<f32>, Vec<(f64, f32)>) {
    let nets = NativePolicy::builtin();
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let mut cfg = TrainConfig::new(Method::Doppler, topo.clone(), 4);
    cfg.seed = 17;
    cfg.episode_batch = batch;
    cfg.update_mode = mode;
    cfg.rollout.threads = threads;
    cfg.rollout.sim_reps = 2;
    cfg.lr = Schedule {
        start: 1e-3,
        end: 1e-4,
    };
    cfg.epsilon = Schedule {
        start: 0.3,
        end: 0.05,
    };
    let mut trainer = doppler::train::Trainer::new(&nets, &g, topo, cfg).unwrap();
    trainer.stage2_sim(16).unwrap();
    assert_eq!(trainer.history.len(), 16);
    assert!(trainer.history.iter().all(|r| r.loss.is_finite()));
    let hist = trainer
        .history
        .iter()
        .map(|r| (r.exec_time, r.loss))
        .collect();
    (trainer.params.clone(), hist)
}

#[test]
fn accumulate_bit_identical_across_thread_counts() {
    let (p1, h1) = run_stage2(1, 4, UpdateMode::Accumulate);
    for threads in [2usize, 4, 8] {
        let (p, h) = run_stage2(threads, 4, UpdateMode::Accumulate);
        assert_eq!(h, h1, "threads={threads}: accumulate history diverged");
        assert_eq!(
            p, p1,
            "threads={threads}: thread count leaked into accumulated params"
        );
    }
}

#[test]
fn accumulate_batch_of_one_matches_sequential_bitwise() {
    // bs = 1: the reduction is the identity and lr.at(start) is the
    // per-episode schedule value, so the two modes must coincide exactly.
    // Both runs drive the same batched entry point (stage2_sim_batch) so
    // episode generation draws identical RNG streams and only the update
    // path differs.
    let run = |mode: UpdateMode| {
        let nets = NativePolicy::builtin();
        let g = chainmm(Scale::Tiny);
        let topo = DeviceTopology::p100x4();
        let mut cfg = TrainConfig::new(Method::Doppler, topo.clone(), 4);
        cfg.seed = 17;
        cfg.episode_batch = 1;
        cfg.update_mode = mode;
        cfg.rollout.threads = 2;
        cfg.rollout.sim_reps = 2;
        cfg.lr = Schedule {
            start: 1e-3,
            end: 1e-4,
        };
        let mut trainer = doppler::train::Trainer::new(&nets, &g, topo, cfg).unwrap();
        for i in 0..10 {
            trainer.stage2_sim_batch(&nets, i, 1, 10, i).unwrap();
        }
        let hist: Vec<(f64, f32)> = trainer
            .history
            .iter()
            .map(|r| (r.exec_time, r.loss))
            .collect();
        (trainer.params.clone(), hist)
    };
    let (ps, hs) = run(UpdateMode::Sequential);
    let (pa, ha) = run(UpdateMode::Accumulate);
    assert_eq!(hs, ha);
    assert_eq!(ps, pa, "single-episode batches must reproduce sequential training");
}

#[test]
fn accumulate_semantics_differ_from_sequential() {
    // one optimizer step per batch vs per episode: with bs > 1 the two
    // modes are INTENTIONALLY different numerics (DESIGN.md §13) — a
    // silent coincidence here would mean the batch path never ran
    let (ps, _) = run_stage2(2, 4, UpdateMode::Sequential);
    let (pa, _) = run_stage2(2, 4, UpdateMode::Accumulate);
    assert_ne!(ps, pa, "accumulate mode should take fewer, larger optimizer steps");
}

/// Generate a batch of real episodes for direct `train_batch` calls
/// (the encoding and episodes own their data; the graph can drop).
fn episode_fixture() -> (
    NativePolicy,
    GraphEncoding,
    Vec<doppler::policy::EpisodeResult>,
    Vec<f32>,
) {
    let nets = NativePolicy::builtin();
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let feats = doppler::features::static_features(&g, &topo, 1.0);
    let variant = nets.variant_for_graph(g.n(), g.m()).unwrap();
    let enc = GraphEncoding::build(&g, &feats, nets.manifest(), &variant).unwrap();
    let params = PolicyBackend::init_params(&nets).unwrap();
    let cfg = EpisodeCfg {
        method: Method::Doppler,
        epsilon: 0.25,
        n_devices: 4,
        per_step_encode: false,
    };
    let eps = doppler::rollout::generate_episodes(
        &nets,
        &enc,
        &g,
        &topo,
        &feats,
        &params,
        &cfg,
        &mut Rng::new(33),
        5,
        2,
    )
    .unwrap();
    (nets, enc, eps, params)
}

#[test]
fn train_batch_invariant_under_item_permutation() {
    let (nets, enc, eps, params) = episode_fixture();
    let variant = nets.variant_for(&enc).unwrap();
    let dm = device_mask(nets.manifest().max_devices, 4);
    let advantages = [0.8f32, -0.3, 0.05, -1.1, 0.6];
    let run = |order: &[usize]| {
        let mut p = params.clone();
        let mut opt = OptState::new(p.len());
        let items: Vec<TrainItem> = order
            .iter()
            .map(|&i| TrainItem {
                traj: &eps[i].trajectory,
                advantage: advantages[i],
            })
            .collect();
        let stats = nets
            .train_batch(
                Method::Doppler,
                &variant,
                &enc,
                &mut p,
                &mut opt,
                &items,
                &dm,
                1e-3,
                1e-2,
                2,
            )
            .unwrap();
        (p, opt, stats)
    };
    let (p0, opt0, s0) = run(&[0, 1, 2, 3, 4]);
    assert_eq!(opt0.t, 1.0, "one optimizer step per batch");
    for order in [[4usize, 3, 2, 1, 0], [2, 0, 4, 1, 3], [1, 4, 0, 3, 2]] {
        let (p, opt, s) = run(&order);
        assert_eq!(p, p0, "order {order:?} leaked into params");
        assert_eq!(opt.m, opt0.m, "order {order:?} leaked into Adam m");
        assert_eq!(opt.v, opt0.v, "order {order:?} leaked into Adam v");
        // stats are per-item: they follow the permutation
        for (j, &i) in order.iter().enumerate() {
            assert_eq!(s[j], s0[i], "stats for episode {i} changed under permutation");
        }
    }
}

#[test]
fn train_batch_single_item_matches_train_step() {
    let (nets, enc, eps, params) = episode_fixture();
    let variant = nets.variant_for(&enc).unwrap();
    let dm = device_mask(nets.manifest().max_devices, 4);

    let mut p_seq = params.clone();
    let mut o_seq = OptState::new(p_seq.len());
    let (l_seq, e_seq) = nets
        .train(
            Method::Doppler,
            &variant,
            &enc,
            &mut p_seq,
            &mut o_seq,
            &eps[0].trajectory,
            &dm,
            0.4,
            1e-3,
            1e-2,
        )
        .unwrap();

    let mut p_bat = params.clone();
    let mut o_bat = OptState::new(p_bat.len());
    let items = [TrainItem {
        traj: &eps[0].trajectory,
        advantage: 0.4,
    }];
    let stats = nets
        .train_batch(
            Method::Doppler,
            &variant,
            &enc,
            &mut p_bat,
            &mut o_bat,
            &items,
            &dm,
            1e-3,
            1e-2,
            4,
        )
        .unwrap();
    assert_eq!(stats, vec![(l_seq, e_seq)]);
    assert_eq!(p_bat, p_seq, "1-item batch must equal one sequential train step");
    assert_eq!(o_bat.m, o_seq.m);
    assert_eq!(o_bat.v, o_seq.v);
    assert_eq!(o_bat.t, o_seq.t);
}

#[test]
fn train_batch_empty_is_a_no_op() {
    let (nets, enc, _eps, params) = episode_fixture();
    let variant = nets.variant_for(&enc).unwrap();
    let dm = device_mask(nets.manifest().max_devices, 4);
    let mut p = params.clone();
    let mut opt = OptState::new(p.len());
    let stats = nets
        .train_batch(Method::Doppler, &variant, &enc, &mut p, &mut opt, &[], &dm, 1e-3, 1e-2, 2)
        .unwrap();
    assert!(stats.is_empty());
    assert_eq!(p, params);
    assert_eq!(opt.t, 0.0);
}

#[test]
fn accumulate_works_for_all_methods() {
    // GDP / PLACETO batches exercise the non-SEL backward paths
    for method in [Method::Gdp, Method::Placeto] {
        let nets = NativePolicy::builtin();
        let g = chainmm(Scale::Tiny);
        let topo = DeviceTopology::p100x4();
        let mut cfg = TrainConfig::new(method, topo.clone(), 4);
        cfg.seed = 5;
        cfg.episode_batch = 3;
        cfg.update_mode = UpdateMode::Accumulate;
        cfg.rollout.threads = 2;
        let mut trainer = doppler::train::Trainer::new(&nets, &g, topo, cfg).unwrap();
        trainer.stage2_sim(6).unwrap();
        assert_eq!(trainer.history.len(), 6, "{method:?}");
        assert!(
            trainer.history.iter().all(|r| r.loss.is_finite()),
            "{method:?}: non-finite loss"
        );
    }
}

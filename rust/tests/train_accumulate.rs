//! Accumulate-mode determinism pins (ISSUE 5 / DESIGN.md §13).
//!
//! `--update-mode accumulate` applies ONE clipped Adam step per episode
//! batch, with per-episode gradients computed in parallel from one
//! parameter snapshot and reduced by IEEE total order. Its contract:
//!
//! - **thread counts never leak** — trained params are bit-identical at
//!   1/2/4/8 rollout threads;
//! - **within-batch episode order never leaks** — permuting the items
//!   handed to `train_batch` permutes the returned stats but leaves the
//!   updated `params`/`opt` bit-identical (the gradient reduction is a
//!   pure function of the multiset of per-episode gradients);
//! - **a single-item batch is exactly one sequential step** — the
//!   reduction degenerates to the identity and the same clipped Adam
//!   tail runs, so `episode_batch = 1` accumulate training reproduces
//!   sequential training bit for bit;
//! - **larger batches are intentionally different numerics** — one
//!   optimizer step per batch, `opt.t` counting batches.
//!
//! `--update-mode accumulate-fused` (DESIGN.md §14 "round 2") keeps the
//! same one-optimizer-step-per-batch semantics but computes encoder
//! weight gradients as fused cross-episode GEMM products over the
//! packed episode batch, reducing in canonical episode-then-row
//! positional order instead of the sorted per-episode multiset. Its
//! pins are the `fused_*` tests below: per-parameter agreement with
//! the per-episode reduction within 1e-6 relative error, bit-identity
//! across 1/2/4/8 rollout threads, bitwise bs = 1 degeneration to a
//! single sequential step, empty-batch no-op, Stage I teacher-episode
//! batching (`opt.t` counts batches), and the one-line stderr fallback
//! to sequential updates on backends without gradient access.
//!
//! Runs entirely on the native backend: zero artifacts required. CI
//! runs this file as a named step in the determinism-pins job, plus a
//! `fused_`-filtered step so the fused pins are visible by name.

use doppler::graph::workloads::{chainmm, Scale};
use doppler::policy::{
    device_mask, EpisodeCfg, GraphEncoding, Method, NativePolicy, OptState, PolicyBackend,
    TrainItem,
};
use doppler::sim::topology::DeviceTopology;
use doppler::train::{Schedule, TrainConfig, UpdateMode};
use doppler::util::rng::Rng;

/// Small accumulate-mode Stage II run; returns (params, history pairs).
fn run_stage2(threads: usize, batch: usize, mode: UpdateMode) -> (Vec<f32>, Vec<(f64, f32)>) {
    let nets = NativePolicy::builtin();
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let mut cfg = TrainConfig::new(Method::Doppler, topo.clone(), 4);
    cfg.seed = 17;
    cfg.episode_batch = batch;
    cfg.update_mode = mode;
    cfg.rollout.threads = threads;
    cfg.rollout.sim_reps = 2;
    cfg.lr = Schedule {
        start: 1e-3,
        end: 1e-4,
    };
    cfg.epsilon = Schedule {
        start: 0.3,
        end: 0.05,
    };
    let mut trainer = doppler::train::Trainer::new(&nets, &g, topo, cfg).unwrap();
    trainer.stage2_sim(16).unwrap();
    assert_eq!(trainer.history.len(), 16);
    assert!(trainer.history.iter().all(|r| r.loss.is_finite()));
    let hist = trainer
        .history
        .iter()
        .map(|r| (r.exec_time, r.loss))
        .collect();
    (trainer.params.clone(), hist)
}

#[test]
fn accumulate_bit_identical_across_thread_counts() {
    let (p1, h1) = run_stage2(1, 4, UpdateMode::Accumulate);
    for threads in [2usize, 4, 8] {
        let (p, h) = run_stage2(threads, 4, UpdateMode::Accumulate);
        assert_eq!(h, h1, "threads={threads}: accumulate history diverged");
        assert_eq!(
            p, p1,
            "threads={threads}: thread count leaked into accumulated params"
        );
    }
}

#[test]
fn accumulate_batch_of_one_matches_sequential_bitwise() {
    // bs = 1: the reduction is the identity and lr.at(start) is the
    // per-episode schedule value, so the two modes must coincide exactly.
    // Both runs drive the same batched entry point (stage2_sim_batch) so
    // episode generation draws identical RNG streams and only the update
    // path differs.
    let run = |mode: UpdateMode| {
        let nets = NativePolicy::builtin();
        let g = chainmm(Scale::Tiny);
        let topo = DeviceTopology::p100x4();
        let mut cfg = TrainConfig::new(Method::Doppler, topo.clone(), 4);
        cfg.seed = 17;
        cfg.episode_batch = 1;
        cfg.update_mode = mode;
        cfg.rollout.threads = 2;
        cfg.rollout.sim_reps = 2;
        cfg.lr = Schedule {
            start: 1e-3,
            end: 1e-4,
        };
        let mut trainer = doppler::train::Trainer::new(&nets, &g, topo, cfg).unwrap();
        for i in 0..10 {
            trainer.stage2_sim_batch(&nets, i, 1, 10, i).unwrap();
        }
        let hist: Vec<(f64, f32)> = trainer
            .history
            .iter()
            .map(|r| (r.exec_time, r.loss))
            .collect();
        (trainer.params.clone(), hist)
    };
    let (ps, hs) = run(UpdateMode::Sequential);
    let (pa, ha) = run(UpdateMode::Accumulate);
    assert_eq!(hs, ha);
    assert_eq!(ps, pa, "single-episode batches must reproduce sequential training");
}

#[test]
fn accumulate_semantics_differ_from_sequential() {
    // one optimizer step per batch vs per episode: with bs > 1 the two
    // modes are INTENTIONALLY different numerics (DESIGN.md §13) — a
    // silent coincidence here would mean the batch path never ran
    let (ps, _) = run_stage2(2, 4, UpdateMode::Sequential);
    let (pa, _) = run_stage2(2, 4, UpdateMode::Accumulate);
    assert_ne!(ps, pa, "accumulate mode should take fewer, larger optimizer steps");
}

/// Generate a batch of real episodes for direct `train_batch` calls
/// (the encoding and episodes own their data; the graph can drop).
fn episode_fixture() -> (
    NativePolicy,
    GraphEncoding,
    Vec<doppler::policy::EpisodeResult>,
    Vec<f32>,
) {
    let nets = NativePolicy::builtin();
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let feats = doppler::features::static_features(&g, &topo, 1.0);
    let variant = nets.variant_for_graph(g.n(), g.m()).unwrap();
    let enc = GraphEncoding::build(&g, &feats, nets.manifest(), &variant).unwrap();
    let params = PolicyBackend::init_params(&nets).unwrap();
    let cfg = EpisodeCfg {
        method: Method::Doppler,
        epsilon: 0.25,
        n_devices: 4,
        per_step_encode: false,
    };
    let eps = doppler::rollout::generate_episodes(
        &nets,
        &enc,
        &g,
        &topo,
        &feats,
        &params,
        &cfg,
        &mut Rng::new(33),
        5,
        2,
    )
    .unwrap();
    (nets, enc, eps, params)
}

#[test]
fn train_batch_invariant_under_item_permutation() {
    let (nets, enc, eps, params) = episode_fixture();
    let variant = nets.variant_for(&enc).unwrap();
    let dm = device_mask(nets.manifest().max_devices, 4);
    let advantages = [0.8f32, -0.3, 0.05, -1.1, 0.6];
    let run = |order: &[usize]| {
        let mut p = params.clone();
        let mut opt = OptState::new(p.len());
        let items: Vec<TrainItem> = order
            .iter()
            .map(|&i| TrainItem {
                traj: &eps[i].trajectory,
                advantage: advantages[i],
            })
            .collect();
        let stats = nets
            .train_batch(
                Method::Doppler,
                &variant,
                &enc,
                &mut p,
                &mut opt,
                &items,
                &dm,
                1e-3,
                1e-2,
                2,
            )
            .unwrap();
        (p, opt, stats)
    };
    let (p0, opt0, s0) = run(&[0, 1, 2, 3, 4]);
    assert_eq!(opt0.t, 1.0, "one optimizer step per batch");
    for order in [[4usize, 3, 2, 1, 0], [2, 0, 4, 1, 3], [1, 4, 0, 3, 2]] {
        let (p, opt, s) = run(&order);
        assert_eq!(p, p0, "order {order:?} leaked into params");
        assert_eq!(opt.m, opt0.m, "order {order:?} leaked into Adam m");
        assert_eq!(opt.v, opt0.v, "order {order:?} leaked into Adam v");
        // stats are per-item: they follow the permutation
        for (j, &i) in order.iter().enumerate() {
            assert_eq!(s[j], s0[i], "stats for episode {i} changed under permutation");
        }
    }
}

#[test]
fn train_batch_single_item_matches_train_step() {
    let (nets, enc, eps, params) = episode_fixture();
    let variant = nets.variant_for(&enc).unwrap();
    let dm = device_mask(nets.manifest().max_devices, 4);

    let mut p_seq = params.clone();
    let mut o_seq = OptState::new(p_seq.len());
    let (l_seq, e_seq) = nets
        .train(
            Method::Doppler,
            &variant,
            &enc,
            &mut p_seq,
            &mut o_seq,
            &eps[0].trajectory,
            &dm,
            0.4,
            1e-3,
            1e-2,
        )
        .unwrap();

    let mut p_bat = params.clone();
    let mut o_bat = OptState::new(p_bat.len());
    let items = [TrainItem {
        traj: &eps[0].trajectory,
        advantage: 0.4,
    }];
    let stats = nets
        .train_batch(
            Method::Doppler,
            &variant,
            &enc,
            &mut p_bat,
            &mut o_bat,
            &items,
            &dm,
            1e-3,
            1e-2,
            4,
        )
        .unwrap();
    assert_eq!(stats, vec![(l_seq, e_seq)]);
    assert_eq!(p_bat, p_seq, "1-item batch must equal one sequential train step");
    assert_eq!(o_bat.m, o_seq.m);
    assert_eq!(o_bat.v, o_seq.v);
    assert_eq!(o_bat.t, o_seq.t);
}

#[test]
fn train_batch_empty_is_a_no_op() {
    let (nets, enc, _eps, params) = episode_fixture();
    let variant = nets.variant_for(&enc).unwrap();
    let dm = device_mask(nets.manifest().max_devices, 4);
    let mut p = params.clone();
    let mut opt = OptState::new(p.len());
    let stats = nets
        .train_batch(Method::Doppler, &variant, &enc, &mut p, &mut opt, &[], &dm, 1e-3, 1e-2, 2)
        .unwrap();
    assert!(stats.is_empty());
    assert_eq!(p, params);
    assert_eq!(opt.t, 0.0);
}

#[test]
fn accumulate_works_for_all_methods() {
    // GDP / PLACETO batches exercise the non-SEL backward paths
    for method in [Method::Gdp, Method::Placeto] {
        let nets = NativePolicy::builtin();
        let g = chainmm(Scale::Tiny);
        let topo = DeviceTopology::p100x4();
        let mut cfg = TrainConfig::new(method, topo.clone(), 4);
        cfg.seed = 5;
        cfg.episode_batch = 3;
        cfg.update_mode = UpdateMode::Accumulate;
        cfg.rollout.threads = 2;
        let mut trainer = doppler::train::Trainer::new(&nets, &g, topo, cfg).unwrap();
        trainer.stage2_sim(6).unwrap();
        assert_eq!(trainer.history.len(), 6, "{method:?}");
        assert!(
            trainer.history.iter().all(|r| r.loss.is_finite()),
            "{method:?}: non-finite loss"
        );
    }
}

// ---------------------------------------------------------------------
// Fused cross-episode backward (`--update-mode accumulate-fused`)
// ---------------------------------------------------------------------

/// Property pin: the fused batch backward's per-parameter gradients
/// match the per-episode-row path within 1e-6 relative error, and the
/// per-item (loss, entropy) stats — produced by the identical head
/// backward in both paths — are bitwise equal.
#[test]
fn fused_gradients_match_accumulate_within_tol() {
    let (nets, enc, eps, params) = episode_fixture();
    let dm = device_mask(nets.manifest().max_devices, 4);
    let advantages = [0.8f32, -0.3, 0.05, -1.1, 0.6];
    let items: Vec<TrainItem> = eps
        .iter()
        .zip(advantages)
        .map(|(ep, advantage)| TrainItem {
            traj: &ep.trajectory,
            advantage,
        })
        .collect();
    let (g_acc, s_acc) = nets
        .batch_gradients(Method::Doppler, &enc, &params, &items, &dm, 1e-2, 2)
        .unwrap();
    let (g_fused, s_fused) = nets
        .batch_gradients_fused(Method::Doppler, &enc, &params, &items, &dm, 1e-2, 2)
        .unwrap();
    assert_eq!(s_fused, s_acc, "head losses must be bitwise identical");
    assert_eq!(g_fused.len(), g_acc.len());
    // both are sums of the same per-episode f32 gradients in different
    // reduction orders: bounded by a relative tolerance against the
    // batch gradient scale (absolute for near-zero parameters)
    let scale = g_acc.iter().fold(1.0f32, |m, g| m.max(g.abs()));
    let mut worst = 0.0f32;
    for (i, (a, f)) in g_acc.iter().zip(&g_fused).enumerate() {
        let err = (a - f).abs() / scale;
        assert!(
            err <= 1e-6,
            "param {i}: accumulate {a} vs fused {f} (rel err {err:e})"
        );
        worst = worst.max(err);
    }
    assert!(worst.is_finite());
}

/// The fused gradient is a pure function of the batch: bit-identical
/// at 1/2/4/8 worker threads (the §14 fixed-order reduction contract
/// extended to packed batch matrices).
#[test]
fn fused_gradients_bitwise_deterministic_across_threads() {
    let (nets, enc, eps, params) = episode_fixture();
    let dm = device_mask(nets.manifest().max_devices, 4);
    let items: Vec<TrainItem> = eps
        .iter()
        .map(|ep| TrainItem {
            traj: &ep.trajectory,
            advantage: 0.7,
        })
        .collect();
    let run = |threads: usize| {
        nets.batch_gradients_fused(Method::Doppler, &enc, &params, &items, &dm, 1e-2, threads)
            .unwrap()
    };
    let (g1, s1) = run(1);
    for threads in [2usize, 4, 8] {
        let (g, s) = run(threads);
        assert_eq!(s, s1, "threads={threads}: fused stats diverged");
        assert_eq!(g, g1, "threads={threads}: thread count leaked into fused gradient");
    }
}

/// End-to-end Stage II pin: whole accumulate-fused training runs are
/// bit-identical across rollout thread counts (CI runs this under the
/// named fused determinism step).
#[test]
fn fused_stage2_bit_identical_across_thread_counts() {
    let (p1, h1) = run_stage2(1, 4, UpdateMode::AccumulateFused);
    for threads in [2usize, 4, 8] {
        let (p, h) = run_stage2(threads, 4, UpdateMode::AccumulateFused);
        assert_eq!(h, h1, "threads={threads}: fused history diverged");
        assert_eq!(
            p, p1,
            "threads={threads}: thread count leaked into fused params"
        );
    }
}

/// bs = 1 degenerate: the packed batch IS the single episode (tiling is
/// a borrow, the positional reduction is a copy), so a one-item fused
/// batch reproduces one sequential train step bit for bit.
#[test]
fn fused_single_item_matches_sequential_train_bitwise() {
    let (nets, enc, eps, params) = episode_fixture();
    let variant = nets.variant_for(&enc).unwrap();
    let dm = device_mask(nets.manifest().max_devices, 4);

    let mut p_seq = params.clone();
    let mut o_seq = OptState::new(p_seq.len());
    let (l_seq, e_seq) = nets
        .train(
            Method::Doppler,
            &variant,
            &enc,
            &mut p_seq,
            &mut o_seq,
            &eps[0].trajectory,
            &dm,
            0.4,
            1e-3,
            1e-2,
        )
        .unwrap();

    let mut p_fused = params.clone();
    let mut o_fused = OptState::new(p_fused.len());
    let items = [TrainItem {
        traj: &eps[0].trajectory,
        advantage: 0.4,
    }];
    let stats = nets
        .train_batch_fused(
            Method::Doppler,
            &variant,
            &enc,
            &mut p_fused,
            &mut o_fused,
            &items,
            &dm,
            1e-3,
            1e-2,
            4,
        )
        .unwrap();
    assert_eq!(stats, vec![(l_seq, e_seq)]);
    assert_eq!(p_fused, p_seq, "1-item fused batch must equal one sequential step");
    assert_eq!(o_fused.m, o_seq.m);
    assert_eq!(o_fused.v, o_seq.v);
    assert_eq!(o_fused.t, o_seq.t);
}

#[test]
fn fused_empty_batch_is_a_no_op() {
    let (nets, enc, _eps, params) = episode_fixture();
    let variant = nets.variant_for(&enc).unwrap();
    let dm = device_mask(nets.manifest().max_devices, 4);
    let mut p = params.clone();
    let mut opt = OptState::new(p.len());
    let stats = nets
        .train_batch_fused(
            Method::Doppler,
            &variant,
            &enc,
            &mut p,
            &mut opt,
            &[],
            &dm,
            1e-3,
            1e-2,
            2,
        )
        .unwrap();
    assert!(stats.is_empty());
    assert_eq!(p, params);
    assert_eq!(opt.t, 0.0);
}

/// The fused reduction is re-blessed numerics: positional
/// episode-ascending f32 sums provably reduce in a different order
/// than accumulate's sorted multiset, and over a full parameter
/// vector the two cannot coincide bitwise. A silent coincidence here
/// would mean the fused path never actually ran.
#[test]
fn fused_reduction_differs_from_accumulate() {
    let (pa, _) = run_stage2(2, 4, UpdateMode::Accumulate);
    let (pf, _) = run_stage2(2, 4, UpdateMode::AccumulateFused);
    assert_ne!(pa, pf, "fused mode should exercise its own reduction order");
}

#[test]
fn fused_works_for_all_methods() {
    // GDP / PLACETO fused batches exercise the non-SEL head backwards
    // feeding the shared fused encoder backward
    for method in [Method::Gdp, Method::Placeto] {
        let nets = NativePolicy::builtin();
        let g = chainmm(Scale::Tiny);
        let topo = DeviceTopology::p100x4();
        let mut cfg = TrainConfig::new(method, topo.clone(), 4);
        cfg.seed = 5;
        cfg.episode_batch = 3;
        cfg.update_mode = UpdateMode::AccumulateFused;
        cfg.rollout.threads = 2;
        let mut trainer = doppler::train::Trainer::new(&nets, &g, topo, cfg).unwrap();
        trainer.stage2_sim(6).unwrap();
        assert_eq!(trainer.history.len(), 6, "{method:?}");
        assert!(
            trainer.history.iter().all(|r| r.loss.is_finite()),
            "{method:?}: non-finite loss"
        );
    }
}

/// Stage I batching: under either accumulate flavor, teacher episodes
/// group into `episode_batch`-sized single-optimizer-step updates —
/// `opt.t` counts batches, history still logs every episode, and the
/// sequential mode keeps stepping once per episode.
#[test]
fn fused_stage1_batches_teacher_episodes() {
    let run = |mode: UpdateMode| {
        let nets = NativePolicy::builtin();
        let g = chainmm(Scale::Tiny);
        let topo = DeviceTopology::p100x4();
        let mut cfg = TrainConfig::new(Method::Doppler, topo.clone(), 4);
        cfg.seed = 11;
        cfg.episode_batch = 4;
        cfg.update_mode = mode;
        cfg.rollout.threads = 2;
        let mut trainer = doppler::train::Trainer::new(&nets, &g, topo, cfg).unwrap();
        trainer.stage1_imitation(8).unwrap();
        assert_eq!(trainer.history.len(), 8, "{mode:?}");
        assert!(
            trainer.history.iter().all(|r| r.loss.is_finite()),
            "{mode:?}: non-finite imitation loss"
        );
        trainer.opt.t
    };
    assert_eq!(run(UpdateMode::Sequential), 8.0, "one step per episode");
    assert_eq!(run(UpdateMode::Accumulate), 2.0, "one step per batch");
    assert_eq!(run(UpdateMode::AccumulateFused), 2.0, "one step per batch");
}

/// A backend with no `Sync` view (the PJRT shape): delegates every
/// call to a wrapped native policy but reports `as_sync() == None`,
/// so batched update modes have no gradient access to batch over.
struct NoSyncBackend(NativePolicy);

impl PolicyBackend for NoSyncBackend {
    fn kind(&self) -> &'static str {
        "no-sync-test"
    }
    fn manifest(&self) -> &doppler::runtime::Manifest {
        PolicyBackend::manifest(&self.0)
    }
    fn variant_for(
        &self,
        enc: &GraphEncoding,
    ) -> anyhow::Result<doppler::runtime::manifest::VariantInfo> {
        PolicyBackend::variant_for(&self.0, enc)
    }
    fn variant_for_graph(
        &self,
        n_nodes: usize,
        n_edges: usize,
    ) -> anyhow::Result<doppler::runtime::manifest::VariantInfo> {
        PolicyBackend::variant_for_graph(&self.0, n_nodes, n_edges)
    }
    fn init_params(&self) -> anyhow::Result<Vec<f32>> {
        PolicyBackend::init_params(&self.0)
    }
    fn encode(
        &self,
        variant: &doppler::runtime::manifest::VariantInfo,
        enc: &GraphEncoding,
        params: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        PolicyBackend::encode(&self.0, variant, enc, params)
    }
    fn sel_scores(
        &self,
        variant: &doppler::runtime::manifest::VariantInfo,
        enc: &GraphEncoding,
        params: &[f32],
        hcat: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        PolicyBackend::sel_scores(&self.0, variant, enc, params, hcat)
    }
    fn begin_episode(
        &self,
        enc: &GraphEncoding,
        params: &[f32],
        hcat: &[f32],
    ) -> anyhow::Result<doppler::policy::EpisodeCache> {
        PolicyBackend::begin_episode(&self.0, enc, params, hcat)
    }
    #[allow(clippy::too_many_arguments)]
    fn plc_logits_step(
        &self,
        variant: &doppler::runtime::manifest::VariantInfo,
        enc: &GraphEncoding,
        cache: &doppler::policy::EpisodeCache,
        params: &[f32],
        hcat: &[f32],
        v_onehot: &[f32],
        xd: &[f32],
        place_norm: &[f32],
        dev_mask: &[f32],
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        PolicyBackend::plc_logits_step(
            &self.0, variant, enc, cache, params, hcat, v_onehot, xd, place_norm, dev_mask, out,
        )
    }
    #[allow(clippy::too_many_arguments)]
    fn gdp_logits_step(
        &self,
        variant: &doppler::runtime::manifest::VariantInfo,
        enc: &GraphEncoding,
        cache: &doppler::policy::EpisodeCache,
        params: &[f32],
        hcat: &[f32],
        v_onehot: &[f32],
        dev_mask: &[f32],
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        PolicyBackend::gdp_logits_step(
            &self.0, variant, enc, cache, params, hcat, v_onehot, dev_mask, out,
        )
    }
    #[allow(clippy::too_many_arguments)]
    fn train(
        &self,
        method: Method,
        variant: &doppler::runtime::manifest::VariantInfo,
        enc: &GraphEncoding,
        params: &mut Vec<f32>,
        opt: &mut OptState,
        traj: &doppler::policy::Trajectory,
        dev_mask: &[f32],
        advantage: f32,
        lr: f32,
        entropy_w: f32,
    ) -> anyhow::Result<(f32, f32)> {
        PolicyBackend::train(
            &self.0, method, variant, enc, params, opt, traj, dev_mask, advantage, lr, entropy_w,
        )
    }
    fn as_sync(&self) -> Option<&(dyn PolicyBackend + Sync)> {
        None
    }
}

/// A batched update mode on a backend without gradient access warns
/// once and degrades to the sequential loop; the degradation is
/// surfaced in `TrainResult::effective_update_mode`. A `Sync` backend
/// keeps the requested mode.
#[test]
fn fused_mode_on_no_sync_backend_falls_back_to_sequential() {
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let stages = doppler::train::Stages {
        imitation: 2,
        sim_rl: 4,
        real_rl: 0,
    };
    let engine_cfg = doppler::engine::EngineConfig::new(topo.clone());
    let mk_cfg = || {
        let mut cfg = TrainConfig::new(Method::Doppler, topo.clone(), 4);
        cfg.seed = 7;
        cfg.episode_batch = 2;
        cfg.update_mode = UpdateMode::AccumulateFused;
        cfg.rollout.threads = 2;
        cfg
    };

    let no_sync = NoSyncBackend(NativePolicy::builtin());
    let trainer = doppler::train::Trainer::new(&no_sync, &g, topo.clone(), mk_cfg()).unwrap();
    let result = trainer.run(stages, &engine_cfg).unwrap();
    assert_eq!(
        result.effective_update_mode,
        UpdateMode::Sequential,
        "no-sync backend must degrade batched modes to sequential"
    );
    assert_eq!(result.history.len(), 6);

    let native = NativePolicy::builtin();
    let trainer = doppler::train::Trainer::new(&native, &g, topo.clone(), mk_cfg()).unwrap();
    let result = trainer.run(stages, &engine_cfg).unwrap();
    assert_eq!(
        result.effective_update_mode,
        UpdateMode::AccumulateFused,
        "a Sync backend keeps the requested update mode"
    );
}

//! Degradation-ladder property tests (DESIGN.md §16): availability and
//! replay determinism of the serving coordinator end to end.
//!
//! - Under ANY injected fault pattern and ANY worker-thread count,
//!   every admitted request is answered with a *valid* assignment
//!   (all nodes placed, devices within topology bounds) and a
//!   correctly-tagged tier — faults degrade quality, never
//!   availability.
//! - A fixed trace + fault plan replays **bit-identically** at
//!   1/2/4/8 worker threads ([`ServeReport::digest`]).
//! - A cache hit returns the bit-identical assignment the cache-miss
//!   path produced for the same canonical hash.
//!
//! The fault plan and its counters are process-global, so every test
//! serializes on one mutex and clears the plan on drop (same harness
//! as tests/resilience.rs).

use std::sync::{Arc, Mutex};

use doppler::graph::workloads::{self, Scale};
use doppler::heuristics::check_assignment;
use doppler::policy::NativePolicy;
use doppler::runtime::resilience::{self, FaultPlan};
use doppler::serve::{synthetic_trace, Coordinator, ServeCfg, ServeReport, ServeRequest, Tier};
use doppler::sim::topology::DeviceTopology;

static LOCK: Mutex<()> = Mutex::new(());

struct PlanGuard<'a> {
    _lock: std::sync::MutexGuard<'a, ()>,
}

impl<'a> PlanGuard<'a> {
    fn acquire() -> PlanGuard<'a> {
        let lock = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        resilience::set_plan(None);
        resilience::reset_stats();
        PlanGuard { _lock: lock }
    }
}

impl Drop for PlanGuard<'_> {
    fn drop(&mut self) {
        resilience::set_plan(None);
        resilience::reset_stats();
    }
}

/// Installing a plan also resets the injection epoch, so each replay
/// sees the identical failure schedule.
fn install(spec: &str) {
    let plan = Arc::new(FaultPlan::parse(spec).unwrap());
    resilience::set_plan(Some(plan));
}

fn mixed_trace(requests: usize) -> Vec<ServeRequest> {
    let ws = vec!["chainmm".to_string(), "ffnn".to_string()];
    synthetic_trace(&ws, Scale::Tiny, requests, 6, 11, 4, None)
}

fn run_with(nets: Option<&NativePolicy>, threads: usize, trace: &[ServeRequest]) -> ServeReport {
    let cfg = ServeCfg {
        threads,
        ..ServeCfg::default()
    };
    let mut c = Coordinator::new(
        cfg,
        DeviceTopology::p100x4(),
        nets.map(|n| n as &dyn doppler::policy::PolicyBackend),
        None,
    )
    .unwrap();
    c.run_trace(trace).unwrap()
}

/// Every response must be a valid placement with a consistent tag,
/// regardless of which tier produced it.
fn assert_all_valid(report: &ServeReport, trace: &[ServeRequest]) {
    let topo_n = DeviceTopology::p100x4().n();
    assert_eq!(
        report.responses.len() + report.rejections.len(),
        trace.len(),
        "every request is either served or explicitly rejected"
    );
    assert_eq!(report.responses.len(), report.metrics.admitted);
    for r in &report.responses {
        let g = workloads::by_name(&r.workload, Scale::Tiny);
        check_assignment(&g, &r.assignment, r.n_devices)
            .unwrap_or_else(|e| panic!("request {}: invalid assignment: {e}", r.request));
        assert!(r.n_devices <= topo_n);
        assert!(r.est_ms.is_finite() && r.est_ms > 0.0);
        match r.tier {
            Tier::Policy => assert!(
                r.policy_attempts >= 1,
                "policy-tier response without a policy attempt"
            ),
            Tier::Cache => assert_eq!(
                r.policy_attempts, 0,
                "cache hit must short-circuit the policy tier"
            ),
            Tier::Heuristic => {}
        }
    }
}

#[test]
fn policy_outage_serves_every_admitted_request_via_lower_tiers() {
    let _guard = PlanGuard::acquire();
    install("seed=5,retries=2,serve.policy=1.0");
    let nets = NativePolicy::builtin();
    let trace = mixed_trace(30);
    let report = run_with(Some(&nets), 4, &trace);
    assert_all_valid(&report, &trace);
    assert_eq!(
        report.metrics.completed, report.metrics.admitted,
        "zero availability loss under a dead policy backend"
    );
    assert!(
        report.responses.iter().all(|r| r.tier != Tier::Policy),
        "a fully-dead policy tier can never produce a response"
    );
    assert!(report.metrics.heuristic_served > 0);
    assert!(resilience::stats().injected > 0, "the plan actually fired");
}

#[test]
fn trace_replays_bit_identically_at_any_thread_count() {
    let _guard = PlanGuard::acquire();
    let nets = NativePolicy::builtin();
    let trace = mixed_trace(36);
    let mut digests = Vec::new();
    let mut tiers: Vec<Vec<Tier>> = Vec::new();
    for &threads in &[1usize, 2, 4, 8] {
        // reinstall per run: set_plan resets the injection epoch, so
        // every replay sees the same failure schedule
        install("seed=9,retries=3,serve.policy=0.4,serve.cache=0.2");
        let report = run_with(Some(&nets), threads, &trace);
        assert_all_valid(&report, &trace);
        digests.push(report.digest());
        tiers.push(report.responses.iter().map(|r| r.tier).collect());
    }
    assert!(
        digests.windows(2).all(|w| w[0] == w[1]),
        "digest must be thread-count independent: {digests:?}"
    );
    assert!(
        tiers.windows(2).all(|w| w[0] == w[1]),
        "tier decisions must be thread-count independent"
    );
}

#[test]
fn cache_hit_is_bit_identical_to_the_cache_miss_result() {
    let _guard = PlanGuard::acquire();
    let nets = NativePolicy::builtin();
    // same workload in two slots: slot 0 misses (policy), slot 1 hits
    let mk = |id: usize, slot: u64| ServeRequest {
        id,
        workload: "chainmm".into(),
        scale: Scale::Tiny,
        slot,
        n_devices: 4,
        deadline_ms: None,
    };
    let trace = vec![mk(0, 0), mk(1, 1)];
    let report = run_with(Some(&nets), 2, &trace);
    assert_eq!(report.responses.len(), 2);
    let (a, b) = (&report.responses[0], &report.responses[1]);
    assert_eq!(a.tier, Tier::Policy);
    assert_eq!(b.tier, Tier::Cache);
    assert_eq!(a.graph_hash, b.graph_hash);
    assert_eq!(
        a.assignment, b.assignment,
        "cache hit must reproduce the cached placement bit-for-bit"
    );
    assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits());
}

#[test]
fn any_fault_pattern_and_thread_count_yields_valid_tagged_responses() {
    let _guard = PlanGuard::acquire();
    let nets = NativePolicy::builtin();
    let trace = mixed_trace(24);
    for (seed, policy_rate, cache_rate) in [
        (1u64, 0.0, 0.0),
        (2, 0.3, 0.0),
        (3, 0.7, 0.5),
        (4, 1.0, 1.0),
    ] {
        for threads in [1usize, 3, 8] {
            install(&format!(
                "seed={seed},retries=2,serve.policy={policy_rate},serve.cache={cache_rate}"
            ));
            let report = run_with(Some(&nets), threads, &trace);
            assert_all_valid(&report, &trace);
            assert_eq!(report.metrics.completed, report.metrics.admitted);
        }
    }
}

#[test]
fn bounded_queue_rejections_are_deterministic() {
    let _guard = PlanGuard::acquire();
    // burst of 12 per slot into a queue of 5 draining 3/slot
    let ws = vec!["chainmm".to_string()];
    let trace = synthetic_trace(&ws, Scale::Tiny, 36, 12, 2, 4, None);
    let run = |threads: usize| {
        let cfg = ServeCfg {
            threads,
            queue_capacity: 5,
            drain_per_slot: 3,
            ..ServeCfg::default()
        };
        let mut c = Coordinator::new(cfg, DeviceTopology::p100x4(), None, None).unwrap();
        c.run_trace(&trace).unwrap()
    };
    let a = run(1);
    let b = run(8);
    assert!(!a.rejections.is_empty(), "overload must actually reject");
    assert_eq!(a.rejections, b.rejections);
    assert_eq!(a.digest(), b.digest());
    for q in &a.rejections {
        assert_eq!(q.capacity, 5);
        assert!(q.backlog >= q.capacity);
    }
}

#[test]
fn zero_deadline_skips_the_policy_tier_but_still_serves() {
    let _guard = PlanGuard::acquire();
    let nets = NativePolicy::builtin();
    let ws = vec!["chainmm".to_string(), "ffnn".to_string()];
    let trace = synthetic_trace(&ws, Scale::Tiny, 10, 4, 3, 4, Some(0));
    let report = run_with(Some(&nets), 2, &trace);
    assert_all_valid(&report, &trace);
    assert_eq!(report.metrics.completed, report.metrics.admitted);
    assert!(
        report
            .responses
            .iter()
            .all(|r| r.tier == Tier::Heuristic && r.policy_attempts == 0 && r.deadline_limited),
        "a zero deadline affords no policy attempts, yet every request is served"
    );
}

//! Kernel-vs-oracle pins for the shared blocked-GEMM module
//! (`policy::gemm`, DESIGN.md §14).
//!
//! The determinism contract says the blocked kernels reorder *loops*,
//! never *reductions*: every output element accumulates its terms in
//! exactly the naive-triple-loop order, so blocked and oracle results are
//! bit-identical for any block size — including degenerate and empty
//! shapes. The property tests below check that contract on random
//! shapes/strides/blockings via the explicit `_with`/`_oracle` entry
//! points (which bypass the process-global config, so they are safe
//! under parallel test execution); the end-to-end test flips the global
//! config around full `run_episode` + `train` calls and is the only test
//! in this binary that touches it.

use doppler::features::static_features;
use doppler::graph::workloads::{chainmm, Scale};
use doppler::policy::gemm::{self, Blocking, KernelConfig, KernelMode, MatDims};
use doppler::policy::{
    device_mask, run_episode, EpisodeCfg, GraphEncoding, Method, NativePolicy, OptState,
    PolicyBackend,
};
use doppler::sim::topology::DeviceTopology;
use doppler::util::rng::Rng;

/// Blockings exercised everywhere: pathological tiles, tiles that divide
/// nothing evenly, zero tiles (clamped to 1), and the default.
const BLOCKINGS: [Blocking; 5] = [
    Blocking { ib: 1, kb: 1, jb: 1 },
    Blocking { ib: 2, kb: 3, jb: 5 },
    Blocking { ib: 8, kb: 16, jb: 8 },
    Blocking { ib: 0, kb: 0, jb: 0 },
    Blocking::DEFAULT,
];

/// Fill with a mix of normals and exact zeros — the kernels' zero-skip
/// paths only matter when zeros actually occur.
fn fill(rng: &mut Rng, buf: &mut [f32]) {
    for v in buf.iter_mut() {
        *v = if rng.chance(0.25) { 0.0 } else { rng.normal() as f32 };
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn prop_gemm_random_shapes_strides_blockings_bitwise() {
    let mut rng = Rng::new(0xB10C_ED);
    for case in 0..60 {
        // shapes 0..=24 so empty-batch (rows == 0) and degenerate inner
        // and col dims all occur with decent probability
        let rows = rng.below(25);
        let inner = rng.below(25);
        let cols = rng.below(25);
        let a_stride = inner + rng.below(4);
        let b_stride = cols + rng.below(4);
        let out_stride = cols + rng.below(4);
        let dims = MatDims::packed(rows, inner, cols)
            .with_a_stride(a_stride.max(1))
            .with_b_stride(b_stride.max(1))
            .with_out_stride(out_stride.max(1));

        let mut a = vec![0.0f32; rows * a_stride.max(1)];
        let mut b = vec![0.0f32; inner * b_stride.max(1)];
        let mut seed = vec![0.0f32; rows * out_stride.max(1)];
        fill(&mut rng, &mut a);
        fill(&mut rng, &mut b);
        fill(&mut rng, &mut seed);

        let mut want_acc = seed.clone();
        gemm::gemm_acc_oracle(&a, &b, dims, &mut want_acc);
        let mut want_assign = seed.clone();
        gemm::gemm_oracle(&a, &b, dims, &mut want_assign);

        for blk in BLOCKINGS {
            let mut got = seed.clone();
            gemm::gemm_acc_with(&a, &b, dims, blk, &mut got);
            assert_eq!(
                bits(&got),
                bits(&want_acc),
                "gemm_acc case {case} ({rows}x{inner}x{cols}) blk {blk:?}"
            );
            let mut got = seed.clone();
            gemm::gemm_with(&a, &b, dims, blk, &mut got);
            assert_eq!(
                bits(&got),
                bits(&want_assign),
                "gemm case {case} ({rows}x{inner}x{cols}) blk {blk:?}"
            );
        }
    }
}

#[test]
fn prop_at_b_and_bt_random_shapes_bitwise() {
    let mut rng = Rng::new(0x7A_B17);
    for case in 0..60 {
        let reduce = rng.below(20);
        let rows = rng.below(20);
        let cols = rng.below(20);

        // Aᵀ·D: a [reduce × rows], d [reduce × cols], out [rows × cols]
        let mut a = vec![0.0f32; reduce * rows];
        let mut d = vec![0.0f32; reduce * cols];
        let mut seed = vec![0.0f32; rows * cols];
        fill(&mut rng, &mut a);
        fill(&mut rng, &mut d);
        fill(&mut rng, &mut seed);
        let mut want = seed.clone();
        gemm::gemm_at_b_acc_oracle(&a, &d, reduce, rows, cols, &mut want);
        for blk in BLOCKINGS {
            let mut got = seed.clone();
            gemm::gemm_at_b_acc_with(&a, &d, reduce, rows, cols, blk, &mut got);
            assert_eq!(bits(&got), bits(&want), "at_b case {case} blk {blk:?}");
        }

        // D·Bᵀ: d [rows × inner], b [cols × inner], out [rows × cols]
        let inner = rng.below(20);
        let mut dm = vec![0.0f32; rows * inner];
        let mut bm = vec![0.0f32; cols * inner];
        fill(&mut rng, &mut dm);
        fill(&mut rng, &mut bm);
        let mut want_acc = seed.clone();
        gemm::gemm_bt_acc_oracle(&dm, &bm, rows, inner, cols, &mut want_acc);
        let mut want_assign = seed.clone();
        gemm::gemm_bt_oracle(&dm, &bm, rows, inner, cols, &mut want_assign);
        for blk in BLOCKINGS {
            let mut got = seed.clone();
            gemm::gemm_bt_acc_with(&dm, &bm, rows, inner, cols, blk, &mut got);
            assert_eq!(bits(&got), bits(&want_acc), "bt_acc case {case} blk {blk:?}");
            let mut got = seed.clone();
            gemm::gemm_bt_with(&dm, &bm, rows, inner, cols, blk, &mut got);
            assert_eq!(bits(&got), bits(&want_assign), "bt case {case} blk {blk:?}");
        }
    }
}

#[test]
fn degenerate_shapes_assign_zero_fills_acc_is_noop() {
    // rows > 0 with inner == 0: assign must zero-fill the output rows,
    // acc must leave them untouched — on both implementations.
    let dims = MatDims::packed(3, 0, 4);
    for blk in BLOCKINGS {
        let mut out = vec![7.0f32; 12];
        gemm::gemm_with(&[], &[], dims, blk, &mut out);
        assert!(out.iter().all(|&x| x == 0.0), "assign blk {blk:?}");
        let mut out = vec![7.0f32; 12];
        gemm::gemm_acc_with(&[], &[], dims, blk, &mut out);
        assert!(out.iter().all(|&x| x == 7.0), "acc blk {blk:?}");
        let mut out = vec![7.0f32; 6];
        gemm::gemm_bt_with(&[], &[], 2, 0, 3, blk, &mut out);
        assert!(out.iter().all(|&x| x == 0.0), "bt blk {blk:?}");
        let mut out = vec![7.0f32; 6];
        gemm::gemm_at_b_acc_with(&[], &[], 0, 2, 3, blk, &mut out);
        assert!(out.iter().all(|&x| x == 7.0), "at_b blk {blk:?}");
    }
    // fully empty: no panics, nothing written
    let mut out: Vec<f32> = vec![];
    gemm::gemm(&[], &[], MatDims::packed(0, 0, 0), &mut out);
    gemm::gemm_bt(&[], &[], 0, 5, 0, &mut out);
    assert!(out.is_empty());
}

#[test]
fn episode_and_train_bit_identical_across_kernel_configs() {
    let nets = NativePolicy::builtin();
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let feats = static_features(&g, &topo, 1.0);
    let variant = nets.variant_for_graph(g.n(), g.m()).unwrap();
    let enc = GraphEncoding::build(&g, &feats, nets.manifest(), &variant).unwrap();
    let params0 = PolicyBackend::init_params(&nets).unwrap();
    let dev_mask = device_mask(nets.manifest().max_devices, 4);
    let cfg = EpisodeCfg {
        method: Method::Doppler,
        epsilon: 0.2,
        n_devices: 4,
        per_step_encode: false,
    };

    // one full episode + one train step under a given kernel config;
    // returns everything observable downstream
    let run = |kcfg: KernelConfig| -> (Vec<usize>, Vec<u32>, Vec<u32>, f32, f32) {
        gemm::set_config(kcfg);
        let mut rng = Rng::new(42);
        let ep = run_episode(&nets, &enc, &g, &topo, &feats, &params0, &cfg, &mut rng).unwrap();
        let mut params = params0.clone();
        let mut opt = OptState::new(params.len());
        let (loss, ent) = nets
            .train(
                Method::Doppler,
                &variant,
                &enc,
                &mut params,
                &mut opt,
                &ep.trajectory,
                &dev_mask,
                1.0,
                1e-3,
                1e-2,
            )
            .unwrap();
        let logits = ep.trajectory.cand_masks.iter().map(|x| x.to_bits()).collect();
        (ep.assignment, logits, bits(&params), loss, ent)
    };

    let prev = gemm::config();
    let base = run(KernelConfig::default());
    let mut configs = vec![KernelConfig {
        mode: KernelMode::Oracle,
        blocking: Blocking::DEFAULT,
    }];
    for blk in BLOCKINGS {
        configs.push(KernelConfig { mode: KernelMode::Blocked, blocking: blk });
    }
    for kcfg in configs {
        let got = run(kcfg);
        assert_eq!(got.0, base.0, "{kcfg:?}: assignment diverged");
        assert_eq!(got.1, base.1, "{kcfg:?}: trajectory diverged");
        assert_eq!(got.2, base.2, "{kcfg:?}: post-train params diverged");
        assert_eq!(got.3.to_bits(), base.3.to_bits(), "{kcfg:?}: loss diverged");
        assert_eq!(got.4.to_bits(), base.4.to_bits(), "{kcfg:?}: entropy diverged");
    }
    gemm::set_config(prev);
}

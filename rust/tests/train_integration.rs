//! End-to-end training integration: Stage I + II + III on CHAINMM-tiny
//! with a small budget must produce an assignment no worse than random
//! and exercise the whole stack. Runs on the native policy backend, so
//! no AOT artifacts (and no PJRT) are required — this is the Stage II
//! "training smoke" guarantee of ISSUE 3.

use doppler::engine::EngineConfig;
use doppler::graph::workloads::{chainmm, Scale};
use doppler::heuristics::random_assignment;
use doppler::policy::{Method, NativePolicy};
use doppler::sim::topology::DeviceTopology;
use doppler::sim::{simulate, SimConfig};
use doppler::train::{Stages, TrainConfig, Trainer};
use doppler::util::rng::Rng;
use doppler::util::stats::mean;

#[test]
fn three_stage_training_improves_over_random() {
    let nets = NativePolicy::builtin();
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let mut cfg = TrainConfig::new(Method::Doppler, topo.clone(), 4);
    cfg.seed = 42;
    // compress the schedules into the small test budget
    cfg.lr = doppler::train::Schedule { start: 1e-3, end: 1e-4 };
    cfg.epsilon = doppler::train::Schedule { start: 0.3, end: 0.05 };

    let trainer = Trainer::new(&nets, &g, topo.clone(), cfg).unwrap();
    let stages = Stages { imitation: 10, sim_rl: 60, real_rl: 10 };
    let engine_cfg = EngineConfig::new(topo.clone());
    let result = trainer.run(stages, &engine_cfg).unwrap();

    assert_eq!(result.best_assignment.len(), g.n());
    assert!(result.best_time.is_finite() && result.best_time > 0.0);
    assert_eq!(result.history.len(), 80);
    assert!(result.history.iter().all(|r| r.loss.is_finite()));

    // compare on the deterministic simulator against mean random
    let sim_cfg = SimConfig::deterministic(topo);
    let mut rng = Rng::new(123);
    let t_best = simulate(&g, &result.best_assignment, &sim_cfg, &mut rng).makespan;
    let rand_times: Vec<f64> = (0..8)
        .map(|s| {
            let mut r = Rng::new(1000 + s);
            let a = random_assignment(&g, 4, &mut r);
            simulate(&g, &a, &sim_cfg, &mut r).makespan
        })
        .collect();
    let t_rand = mean(&rand_times);
    assert!(
        t_best < t_rand,
        "trained best ({t_best:.4}s) should beat mean random ({t_rand:.4}s)"
    );

    // stage markers present in the history
    assert!(result.history.iter().any(|r| r.stage == 1));
    assert!(result.history.iter().any(|r| r.stage == 2));
    assert!(result.history.iter().any(|r| r.stage == 3));
}

/// Batched Stage II (episode_batch > 1, native backend) must remain a
/// pure function of the seed: thread count never changes anything, and
/// the run completes with finite losses.
#[test]
fn batched_stage2_deterministic_across_thread_counts() {
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let run = |threads: usize| {
        let nets = NativePolicy::builtin();
        let mut cfg = TrainConfig::new(Method::Doppler, topo.clone(), 4);
        cfg.seed = 9;
        cfg.episode_batch = 4;
        cfg.rollout.threads = threads;
        let mut trainer = Trainer::new(&nets, &g, topo.clone(), cfg).unwrap();
        trainer.stage2_sim(12).unwrap();
        assert_eq!(trainer.history.len(), 12);
        assert!(trainer.history.iter().all(|r| r.loss.is_finite()));
        (
            trainer.params.clone(),
            trainer
                .history
                .iter()
                .map(|r| (r.exec_time, r.loss))
                .collect::<Vec<_>>(),
        )
    };
    let (p1, h1) = run(1);
    let (p4, h4) = run(4);
    assert_eq!(h1, h4, "thread count leaked into batched Stage II history");
    assert_eq!(p1, p4, "thread count leaked into trained parameters");
}

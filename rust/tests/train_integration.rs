//! End-to-end training integration: Stage I + II + III on CHAINMM-tiny
//! with a small budget must produce an assignment no worse than random
//! and exercise the whole stack. Runs on the native policy backend, so
//! no AOT artifacts (and no PJRT) are required — this is the Stage II
//! "training smoke" guarantee of ISSUE 3.

use doppler::engine::EngineConfig;
use doppler::graph::workloads::{chainmm, Scale};
use doppler::heuristics::random_assignment;
use doppler::policy::{Method, NativePolicy, PolicyBackend};
use doppler::sim::topology::DeviceTopology;
use doppler::sim::{simulate, SimConfig};
use doppler::train::multi::{zero_shot_assignment, MultiGraphTrainer, MultiTrainCfg, WorkloadSet};
use doppler::train::{Stages, TrainConfig, Trainer};
use doppler::util::rng::Rng;
use doppler::util::stats::mean;

#[test]
fn three_stage_training_improves_over_random() {
    let nets = NativePolicy::builtin();
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let mut cfg = TrainConfig::new(Method::Doppler, topo.clone(), 4);
    cfg.seed = 42;
    // compress the schedules into the small test budget
    cfg.lr = doppler::train::Schedule {
        start: 1e-3,
        end: 1e-4,
    };
    cfg.epsilon = doppler::train::Schedule {
        start: 0.3,
        end: 0.05,
    };

    let trainer = Trainer::new(&nets, &g, topo.clone(), cfg).unwrap();
    let stages = Stages {
        imitation: 10,
        sim_rl: 60,
        real_rl: 10,
    };
    let engine_cfg = EngineConfig::new(topo.clone());
    let result = trainer.run(stages, &engine_cfg).unwrap();

    assert_eq!(result.best_assignment.len(), g.n());
    assert!(result.best_time.is_finite() && result.best_time > 0.0);
    assert_eq!(result.history.len(), 80);
    assert!(result.history.iter().all(|r| r.loss.is_finite()));

    // compare on the deterministic simulator against mean random
    let sim_cfg = SimConfig::deterministic(topo);
    let mut rng = Rng::new(123);
    let t_best = simulate(&g, &result.best_assignment, &sim_cfg, &mut rng).makespan;
    let rand_times: Vec<f64> = (0..8)
        .map(|s| {
            let mut r = Rng::new(1000 + s);
            let a = random_assignment(&g, 4, &mut r);
            simulate(&g, &a, &sim_cfg, &mut r).makespan
        })
        .collect();
    let t_rand = mean(&rand_times);
    assert!(
        t_best < t_rand,
        "trained best ({t_best:.4}s) should beat mean random ({t_rand:.4}s)"
    );

    // stage markers present in the history
    assert!(result.history.iter().any(|r| r.stage == 1));
    assert!(result.history.iter().any(|r| r.stage == 2));
    assert!(result.history.iter().any(|r| r.stage == 3));
}

/// Batched Stage II (episode_batch > 1, native backend) must remain a
/// pure function of the seed: thread count never changes anything, and
/// the run completes with finite losses.
#[test]
fn batched_stage2_deterministic_across_thread_counts() {
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let run = |threads: usize| {
        let nets = NativePolicy::builtin();
        let mut cfg = TrainConfig::new(Method::Doppler, topo.clone(), 4);
        cfg.seed = 9;
        cfg.episode_batch = 4;
        cfg.rollout.threads = threads;
        let mut trainer = Trainer::new(&nets, &g, topo.clone(), cfg).unwrap();
        trainer.stage2_sim(12).unwrap();
        assert_eq!(trainer.history.len(), 12);
        assert!(trainer.history.iter().all(|r| r.loss.is_finite()));
        (
            trainer.params.clone(),
            trainer
                .history
                .iter()
                .map(|r| (r.exec_time, r.loss))
                .collect::<Vec<_>>(),
        )
    };
    let (p1, h1) = run(1);
    let (p4, h4) = run(4);
    assert_eq!(h1, h4, "thread count leaked into batched Stage II history");
    assert_eq!(p1, p4, "thread count leaked into trained parameters");
}

/// The headline transfer claim (Table 4 protocol), miniaturized: one
/// shared blob trained across the built-in `tiny` suite, deployed
/// *zero-shot* on the suite's held-out graph (no retraining on it),
/// must beat the untrained He-init blob deployed the same way.
#[test]
fn multi_graph_shared_params_beat_untrained_init_on_holdout() {
    let nets = NativePolicy::builtin();
    let set = WorkloadSet::builtin("tiny").unwrap();
    let first = &set.train[0];
    let mut base = TrainConfig::new(
        Method::Doppler,
        first.build_topology().unwrap(),
        first.n_devices,
    );
    base.seed = 11;
    base.episode_batch = 4;
    base.rollout.threads = 2;
    base.rollout.sim_reps = 2;
    // compress the schedules into the small test budget
    base.lr = doppler::train::Schedule {
        start: 1e-3,
        end: 1e-4,
    };
    base.epsilon = doppler::train::Schedule {
        start: 0.3,
        end: 0.05,
    };
    // imitation-heavy: at tiny budgets the CRITICAL PATH teacher is the
    // most transferable signal, which is what zero-shot deployment tests
    let stages = Stages {
        imitation: 24,
        sim_rl: 40,
        real_rl: 0,
    };
    let result = MultiGraphTrainer::new(&nets, &set, MultiTrainCfg { base, stages })
        .run()
        .unwrap();
    assert_eq!(result.total_episodes, 64);
    assert_eq!(result.reports.len(), set.train.len());
    assert!(result.reports.iter().all(|r| r.episodes > 0));
    assert!(result
        .reports
        .iter()
        .flat_map(|r| &r.history)
        .all(|row| row.loss.is_finite()));

    // zero-shot deployment on the held-out graph
    let hold = &set.holdout[0];
    let g = hold.build_graph().unwrap();
    let sub = hold.build_topology().unwrap();
    let mut scratch = doppler::policy::EpisodeScratch::new();
    let init = PolicyBackend::init_params(&nets).unwrap();
    let a_init = zero_shot_assignment(
        &nets,
        &g,
        &sub,
        hold.n_devices,
        Method::Doppler,
        &init,
        &mut scratch,
    )
    .unwrap();
    let a_shared = zero_shot_assignment(
        &nets,
        &g,
        &sub,
        hold.n_devices,
        Method::Doppler,
        &result.params,
        &mut scratch,
    )
    .unwrap();
    assert_eq!(a_shared.len(), g.n());

    // compare on the deterministic simulator (same clock for both)
    let sim_cfg = SimConfig::deterministic(sub);
    let t_init = simulate(&g, &a_init, &sim_cfg, &mut Rng::new(5)).makespan;
    let t_shared = simulate(&g, &a_shared, &sim_cfg, &mut Rng::new(5)).makespan;
    assert!(
        t_shared < t_init,
        "zero-shot shared params ({t_shared:.4}s) should beat the untrained \
         init ({t_init:.4}s) on held-out {}",
        g.name
    );
}

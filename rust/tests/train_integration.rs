//! End-to-end training integration: Stage I + II + III on CHAINMM-tiny
//! with a small budget must produce an assignment no worse than random
//! and exercise the whole three-layer stack. Requires `make artifacts`.

use doppler::engine::EngineConfig;
use doppler::graph::workloads::{chainmm, Scale};
use doppler::heuristics::random_assignment;
use doppler::policy::{Method, PolicyNets};
use doppler::sim::topology::DeviceTopology;
use doppler::sim::{simulate, SimConfig};
use doppler::train::{Stages, TrainConfig, Trainer};
use doppler::util::rng::Rng;
use doppler::util::stats::mean;

#[test]
fn three_stage_training_improves_over_random() {
    let Ok(nets) = PolicyNets::load_default() else {
        eprintln!("SKIP train integration (run `make artifacts`)");
        return;
    };
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let mut cfg = TrainConfig::new(Method::Doppler, topo.clone(), 4);
    cfg.seed = 42;
    // compress the schedules into the small test budget
    cfg.lr = doppler::train::Schedule { start: 1e-3, end: 1e-4 };
    cfg.epsilon = doppler::train::Schedule { start: 0.3, end: 0.05 };

    let trainer = Trainer::new(&nets, &g, topo.clone(), cfg).unwrap();
    let stages = Stages { imitation: 10, sim_rl: 60, real_rl: 10 };
    let engine_cfg = EngineConfig::new(topo.clone());
    let result = trainer.run(stages, &engine_cfg).unwrap();

    assert_eq!(result.best_assignment.len(), g.n());
    assert!(result.best_time.is_finite() && result.best_time > 0.0);
    assert_eq!(result.history.len(), 80);
    assert!(result.history.iter().all(|r| r.loss.is_finite()));

    // compare on the deterministic simulator against mean random
    let sim_cfg = SimConfig::deterministic(topo);
    let mut rng = Rng::new(123);
    let t_best = simulate(&g, &result.best_assignment, &sim_cfg, &mut rng).makespan;
    let rand_times: Vec<f64> = (0..8)
        .map(|s| {
            let mut r = Rng::new(1000 + s);
            let a = random_assignment(&g, 4, &mut r);
            simulate(&g, &a, &sim_cfg, &mut r).makespan
        })
        .collect();
    let t_rand = mean(&rand_times);
    assert!(
        t_best < t_rand,
        "trained best ({t_best:.4}s) should beat mean random ({t_rand:.4}s)"
    );

    // stage markers present in the history
    assert!(result.history.iter().any(|r| r.stage == 1));
    assert!(result.history.iter().any(|r| r.stage == 2));
    assert!(result.history.iter().any(|r| r.stage == 3));
}

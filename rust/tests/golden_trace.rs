//! Golden-trace regression: the deterministic work-conserving schedule of
//! CHAINMM(Tiny) under `SimConfig::deterministic` (zero jitter, FIFO
//! choose) is pinned event-by-event in a committed JSON fixture, so any
//! future scheduler change that silently shifts `ExecTime` — reordered
//! task enumeration, cost-model edits, heap tie-break changes — fails
//! loudly here instead of quietly perturbing every training reward.
//!
//! Re-bless after an *intentional* scheduler change with either
//!   cargo test -q --test golden_trace -- --ignored bless_golden_trace
//! or `python3 tools/gen_golden_trace.py` (an independent port of the
//! deterministic simulator; both produce the same trace).

use doppler::graph::workloads::{chainmm, Scale};
use doppler::graph::Graph;
use doppler::sim::topology::DeviceTopology;
use doppler::sim::{simulate, SimConfig, SimResult};
use doppler::util::json::{self, Json};
use doppler::util::rng::Rng;

const FIXTURE: &str = concat!(
    env!("CARGO_MANIFEST_DIR"),
    "/tests/fixtures/golden_trace_chainmm_tiny.json"
);

fn run_reference() -> (Graph, SimResult) {
    let g = chainmm(Scale::Tiny);
    let a: Vec<usize> = (0..g.n()).map(|v| v % 4).collect();
    let cfg = SimConfig::deterministic(DeviceTopology::p100x4());
    // deterministic + FIFO never consumes the RNG; seed 0 documents that
    let r = simulate(&g, &a, &cfg, &mut Rng::new(0));
    (g, r)
}

/// Relative comparison for times that should be bit-identical; the
/// tolerance only absorbs decimal serialization, not scheduling drift.
fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()) + 1e-15
}

fn field(row: &Json, i: usize) -> f64 {
    row.as_arr().expect("fixture row is an array")[i]
        .as_f64()
        .expect("fixture cell is a number")
}

#[test]
fn golden_trace_replays_event_by_event() {
    let text = std::fs::read_to_string(FIXTURE)
        .unwrap_or_else(|e| panic!("missing fixture {FIXTURE}: {e} (see module docs to bless)"));
    let fx = json::parse(&text).expect("fixture parses");

    let (g, r) = run_reference();
    assert_eq!(fx.get("n_nodes").as_usize(), Some(g.n()), "graph shape changed");
    assert_eq!(fx.get("n_edges").as_usize(), Some(g.m()), "graph shape changed");

    // scalar summary first: cheapest signal when something moved
    let makespan = fx.get("makespan").as_f64().unwrap();
    assert!(
        close(r.makespan, makespan),
        "makespan drifted: got {} fixture {}",
        r.makespan,
        makespan
    );
    let bytes = fx.get("bytes_moved").as_f64().unwrap();
    assert!(
        close(r.bytes_moved, bytes),
        "bytes_moved drifted: got {} fixture {}",
        r.bytes_moved,
        bytes
    );

    // exec events, in completion order: [node, device, start, end]
    let execs = fx.get("execs").as_arr().expect("execs array");
    assert_eq!(r.execs.len(), execs.len(), "exec event count changed");
    for (i, (got, want)) in r.execs.iter().zip(execs).enumerate() {
        assert_eq!(got.node as f64, field(want, 0), "exec {i}: node");
        assert_eq!(got.device as f64, field(want, 1), "exec {i}: device");
        assert!(
            close(got.start, field(want, 2)),
            "exec {i} (node {}): start {} != {}",
            got.node,
            got.start,
            field(want, 2)
        );
        assert!(
            close(got.end, field(want, 3)),
            "exec {i} (node {}): end {} != {}",
            got.node,
            got.end,
            field(want, 3)
        );
    }

    // transfer events, in completion order: [node, from, to, start, end]
    let transfers = fx.get("transfers").as_arr().expect("transfers array");
    assert_eq!(r.transfers.len(), transfers.len(), "transfer event count changed");
    for (i, (got, want)) in r.transfers.iter().zip(transfers).enumerate() {
        assert_eq!(got.node as f64, field(want, 0), "transfer {i}: node");
        assert_eq!(got.from as f64, field(want, 1), "transfer {i}: from");
        assert_eq!(got.to as f64, field(want, 2), "transfer {i}: to");
        assert!(
            close(got.start, field(want, 3)),
            "transfer {i} (node {}): start {} != {}",
            got.node,
            got.start,
            field(want, 3)
        );
        assert!(
            close(got.end, field(want, 4)),
            "transfer {i} (node {}): end {} != {}",
            got.node,
            got.end,
            field(want, 4)
        );
    }
}

/// The deterministic trace must also be independent of the seed (zero
/// jitter + FIFO never touch the RNG) — the precondition that makes a
/// single committed fixture meaningful.
#[test]
fn deterministic_trace_ignores_seed() {
    let g = chainmm(Scale::Tiny);
    let a: Vec<usize> = (0..g.n()).map(|v| v % 4).collect();
    let cfg = SimConfig::deterministic(DeviceTopology::p100x4());
    let r1 = simulate(&g, &a, &cfg, &mut Rng::new(0));
    let r2 = simulate(&g, &a, &cfg, &mut Rng::new(0xDEADBEEF));
    assert_eq!(r1.makespan, r2.makespan);
    assert_eq!(r1.execs.len(), r2.execs.len());
    for (x, y) in r1.execs.iter().zip(&r2.execs) {
        assert_eq!((x.node, x.device, x.start, x.end), (y.node, y.device, y.start, y.end));
    }
    for (x, y) in r1.transfers.iter().zip(&r2.transfers) {
        assert_eq!((x.node, x.from, x.to), (y.node, y.from, y.to));
        assert_eq!((x.start, x.end), (y.start, y.end));
    }
}

/// Rewrite the fixture from a live run. `#[ignore]`d: run explicitly
/// after an intentional scheduler change, then commit the diff.
#[test]
#[ignore]
fn bless_golden_trace() {
    let (g, r) = run_reference();
    let execs: Vec<Json> = r
        .execs
        .iter()
        .map(|e| {
            Json::Arr(vec![
                json::num(e.node as f64),
                json::num(e.device as f64),
                json::num(e.start),
                json::num(e.end),
            ])
        })
        .collect();
    let transfers: Vec<Json> = r
        .transfers
        .iter()
        .map(|t| {
            Json::Arr(vec![
                json::num(t.node as f64),
                json::num(t.from as f64),
                json::num(t.to as f64),
                json::num(t.start),
                json::num(t.end),
            ])
        })
        .collect();
    let fx = json::obj(vec![
        ("workload", json::s("chainmm")),
        ("scale", json::s("tiny")),
        ("topology", json::s("p100x4")),
        ("sim_config", json::s("deterministic+fifo")),
        ("assignment", json::s("node_id mod 4")),
        ("seed", json::num(0.0)),
        ("n_nodes", json::num(g.n() as f64)),
        ("n_edges", json::num(g.m() as f64)),
        ("makespan", json::num(r.makespan)),
        ("bytes_moved", json::num(r.bytes_moved)),
        ("execs", Json::Arr(execs)),
        ("transfers", Json::Arr(transfers)),
    ]);
    std::fs::write(FIXTURE, fx.to_string()).expect("writing fixture");
    eprintln!("blessed {FIXTURE}");
}

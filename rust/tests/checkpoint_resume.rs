//! Kill-and-resume determinism pins (DESIGN.md §15).
//!
//! The contract under test: a run that is interrupted at a checkpoint
//! boundary (simulated with `CheckpointCfg::halt_after`) and then
//! resumed with `--resume` must produce **bit-identical** parameters,
//! optimizer state, and history to the same run executed without
//! interruption — in sequential and accumulate update modes, and for
//! the multi-graph trainer. Plus the container-level guarantees: CRC
//! validation rejects corruption, and history CSVs are written
//! atomically.

use doppler::engine::EngineConfig;
use doppler::graph::workloads::{chainmm, Scale};
use doppler::policy::{Method, NativePolicy};
use doppler::runtime::checkpoint::{self, CheckpointCfg, Interrupted};
use doppler::sim::topology::DeviceTopology;
use doppler::train::multi::{MultiGraphTrainer, MultiTrainCfg, WorkloadSet};
use doppler::train::{LogRow, Stages, TrainConfig, Trainer, UpdateMode};

/// Fresh per-test scratch directory (removed and recreated on entry so
/// a previous failed run can never satisfy a resume).
fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("doppler-ckpt-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn history_key(history: &[LogRow]) -> Vec<(usize, u8, f64, f64, f32, f32, usize, usize)> {
    history
        .iter()
        .map(|r| {
            (
                r.episode,
                r.stage,
                r.exec_time,
                r.best_time,
                r.loss,
                r.entropy,
                r.encode_calls,
                r.anomalies,
            )
        })
        .collect()
}

/// One single-graph training run to completion (or until `halt_after`
/// interrupts it). All non-checkpoint knobs are fixed so runs differ
/// only in their checkpoint policy.
fn run_trainer(
    mode: UpdateMode,
    batch: usize,
    stages: Stages,
    ck: Option<CheckpointCfg>,
) -> anyhow::Result<(Vec<f32>, Vec<(usize, u8, f64, f64, f32, f32, usize, usize)>, f64)> {
    let nets = NativePolicy::builtin();
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let mut cfg = TrainConfig::new(Method::Doppler, topo.clone(), 4);
    cfg.seed = 13;
    cfg.update_mode = mode;
    cfg.episode_batch = batch;
    cfg.rollout.threads = 2;
    cfg.rollout.sim_reps = 2;
    cfg.lr = doppler::train::Schedule {
        start: 1e-3,
        end: 1e-4,
    };
    cfg.checkpoint = ck;
    let trainer = Trainer::new(&nets, &g, topo.clone(), cfg)?;
    let engine_cfg = EngineConfig::new(topo);
    let result = trainer.run(stages, &engine_cfg)?;
    Ok((result.params, history_key(&result.history), result.best_time))
}

#[test]
fn sequential_kill_and_resume_is_bit_identical() {
    let dir = temp_dir("seq");
    let stages = Stages {
        imitation: 4,
        sim_rl: 10,
        real_rl: 0,
    };

    // golden: uninterrupted, no checkpointing at all
    let golden = run_trainer(UpdateMode::Sequential, 1, stages, None).unwrap();

    // interrupted: checkpoint every 5 episodes, simulated kill at 7
    let mut ck = CheckpointCfg::new(&dir);
    ck.every = 5;
    ck.halt_after = Some(7);
    let err = run_trainer(UpdateMode::Sequential, 1, stages, Some(ck))
        .expect_err("halt_after must interrupt the run");
    let int = err
        .downcast_ref::<Interrupted>()
        .expect("interrupt must surface as the typed Interrupted error");
    assert_eq!(int.episodes_done, 7, "sequential halt fires exactly at the boundary");
    assert!(int.path.exists(), "the interrupting halt must have written its blob");

    // resumed: same run config, resume on, kill switch off
    let mut ck = CheckpointCfg::new(&dir);
    ck.every = 5;
    ck.resume = true;
    let resumed = run_trainer(UpdateMode::Sequential, 1, stages, Some(ck)).unwrap();

    assert_eq!(resumed.0, golden.0, "resumed params drifted from the golden run");
    assert_eq!(resumed.1, golden.1, "resumed history drifted from the golden run");
    assert_eq!(
        resumed.2.to_bits(),
        golden.2.to_bits(),
        "resumed best_time drifted from the golden run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn accumulate_kill_and_resume_is_bit_identical() {
    let dir = temp_dir("acc");
    let stages = Stages {
        imitation: 0,
        sim_rl: 12,
        real_rl: 0,
    };

    let golden = run_trainer(UpdateMode::Accumulate, 4, stages, None).unwrap();

    // batched path: checkpoints land on batch boundaries (4, 8, 12);
    // the kill at >= 8 episodes fires after the second batch
    let mut ck = CheckpointCfg::new(&dir);
    ck.every = 4;
    ck.halt_after = Some(8);
    let err = run_trainer(UpdateMode::Accumulate, 4, stages, Some(ck))
        .expect_err("halt_after must interrupt the batched run");
    let int = err
        .downcast_ref::<Interrupted>()
        .expect("interrupt must surface as the typed Interrupted error");
    assert_eq!(int.episodes_done, 8, "batched halt fires at the batch boundary");

    let mut ck = CheckpointCfg::new(&dir);
    ck.every = 4;
    ck.resume = true;
    let resumed = run_trainer(UpdateMode::Accumulate, 4, stages, Some(ck)).unwrap();

    assert_eq!(resumed.0, golden.0, "resumed accumulate params drifted");
    assert_eq!(resumed.1, golden.1, "resumed accumulate history drifted");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Multi-graph kill-and-resume: interrupt mid-Stage II (between
/// interleave rounds) and resume; the shared blob and every member
/// history must match the uninterrupted run bit-for-bit.
#[test]
fn multi_graph_kill_and_resume_is_bit_identical() {
    let dir = temp_dir("multi");
    let nets = NativePolicy::builtin();
    let set = WorkloadSet::builtin("tiny").unwrap();
    let first = &set.train[0];
    let stages = Stages {
        imitation: 8,
        sim_rl: 12,
        real_rl: 0,
    };
    let base_cfg = |ck: Option<CheckpointCfg>| {
        let mut base = TrainConfig::new(
            Method::Doppler,
            first.build_topology().unwrap(),
            first.n_devices,
        );
        base.seed = 23;
        base.episode_batch = 2;
        base.rollout.threads = 2;
        base.rollout.sim_reps = 2;
        base.lr = doppler::train::Schedule {
            start: 1e-3,
            end: 1e-4,
        };
        base.checkpoint = ck;
        base
    };
    let run = |ck: Option<CheckpointCfg>| {
        MultiGraphTrainer::new(&nets, &set, MultiTrainCfg {
            base: base_cfg(ck),
            stages,
        })
        .run()
    };

    let golden = run(None).unwrap();

    // Stage I contributes 8 episodes; the first Stage II round boundary
    // lands at 8 + 6 = 14 global episodes, which trips the >= 13 kill —
    // an interrupt in the middle of the Stage II rotation.
    let mut ck = CheckpointCfg::new(&dir);
    ck.every = 4;
    ck.halt_after = Some(13);
    let err = run(Some(ck)).expect_err("halt_after must interrupt the multi run");
    let int = err
        .downcast_ref::<Interrupted>()
        .expect("interrupt must surface as the typed Interrupted error");
    assert_eq!(int.episodes_done, 14, "multi halt fires at a round boundary");
    assert!(int.path.exists());

    let mut ck = CheckpointCfg::new(&dir);
    ck.every = 4;
    ck.resume = true;
    let resumed = run(Some(ck)).unwrap();

    assert_eq!(resumed.params, golden.params, "resumed shared blob drifted");
    assert_eq!(resumed.total_episodes, golden.total_episodes);
    assert_eq!(resumed.reports.len(), golden.reports.len());
    for (r, g) in resumed.reports.iter().zip(&golden.reports) {
        assert_eq!(r.name, g.name);
        assert_eq!(r.episodes, g.episodes, "workload {}: episode count drifted", r.name);
        assert_eq!(
            history_key(&r.history),
            history_key(&g.history),
            "workload {}: resumed history drifted",
            r.name
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The checkpoint container must reject bit rot and truncation loudly —
/// silently resuming from a damaged blob would corrupt the run it was
/// meant to save.
#[test]
fn corrupt_checkpoints_are_rejected() {
    let dir = temp_dir("corrupt");
    let path = dir.join("blob.ckpt");
    let payload = b"checkpoint payload bytes for crc validation".to_vec();
    checkpoint::save_atomic(&path, &payload).unwrap();
    assert_eq!(checkpoint::load(&path).unwrap(), payload);

    // flip one payload bit -> CRC failure
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[16 + 3] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    let e = checkpoint::load(&path).expect_err("bit rot must fail validation");
    assert!(format!("{e:#}").contains("CRC"), "unexpected error: {e:#}");

    // truncate -> length failure
    checkpoint::save_atomic(&path, &payload).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() - 2]).unwrap();
    let e = checkpoint::load(&path).expect_err("truncation must fail validation");
    assert!(format!("{e:#}").contains("length mismatch"), "unexpected error: {e:#}");

    // wrong magic -> not-a-checkpoint failure
    let mut bytes = std::fs::read(&path).unwrap();
    bytes.truncate(0);
    bytes.extend_from_slice(b"NOTACKPT");
    std::fs::write(&path, &bytes).unwrap();
    let e = checkpoint::load(&path).expect_err("wrong magic must fail validation");
    assert!(format!("{e:#}").contains("truncated") || format!("{e:#}").contains("magic"));
    let _ = std::fs::remove_dir_all(&dir);
}

/// `write_history_csv` goes through the atomic temp-file + rename path:
/// the final file is complete and no temp file is left behind.
#[test]
fn history_csv_is_written_atomically() {
    let dir = temp_dir("csv");
    let path = dir.join("history.csv");
    let rows = vec![
        LogRow {
            episode: 0,
            stage: 1,
            exec_time: 0.5,
            best_time: 0.5,
            loss: 1.25,
            entropy: 0.9,
            encode_calls: 1,
            anomalies: 0,
        },
        LogRow {
            episode: 1,
            stage: 2,
            exec_time: 0.4,
            best_time: 0.4,
            loss: f32::NAN,
            entropy: f32::NAN,
            encode_calls: 2,
            anomalies: 1,
        },
    ];
    doppler::train::write_history_csv(&path, &rows).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 3);
    assert_eq!(
        lines[0],
        "episode,stage,exec_time_ms,best_time_ms,loss,entropy,encode_calls,anomalies"
    );
    assert!(lines[1].starts_with("0,1,"));
    assert!(lines[2].ends_with(",2,1"), "anomaly count missing: {}", lines[2]);

    // no temp droppings in the directory
    let leftovers: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.file_name().to_string_lossy().contains(".tmp"))
        .collect();
    assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

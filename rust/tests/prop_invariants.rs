//! Property-based invariant tests (hand-rolled generators — the offline
//! image has no proptest): randomized sweeps over graphs, seeds, device
//! counts and jitter levels asserting the invariants the whole system
//! rests on. Each property runs across many seeded cases; failures print
//! the offending seed for reproduction.

use doppler::features::{static_features, AssignState};
use doppler::graph::workloads::{by_name, synthetic_layered, Scale, WORKLOADS};
use doppler::graph::{Assignment, Graph};
use doppler::heuristics::{
    check_assignment, critical_path_once, enumerative_optimizer, random_assignment, round_robin,
};
use doppler::rollout;
use doppler::sim::bulksync::bulksync_exec;
use doppler::sim::topology::DeviceTopology;
use doppler::sim::{simulate, Choose, Engine, SimConfig, SimResult};
use doppler::util::rng::Rng;

fn random_graph(seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let n = 40 + rng.below(160);
    synthetic_layered(n, seed)
}

fn random_valid_assignment(g: &Graph, nd: usize, rng: &mut Rng) -> Assignment {
    random_assignment(g, nd, rng)
}

/// Dependencies are never violated in any simulated schedule, for any
/// graph, assignment, scheduler strategy, or jitter level.
#[test]
fn prop_sim_respects_dependencies() {
    for seed in 0..25u64 {
        let g = random_graph(seed);
        let mut rng = Rng::new(seed ^ 0xAB);
        let nd = 2 + rng.below(7);
        let a = random_valid_assignment(&g, nd, &mut rng);
        let mut cfg = SimConfig::new(doppler::eval::restrict(&DeviceTopology::v100x8(), nd));
        cfg.jitter_sigma = [0.0, 0.05, 0.3][seed as usize % 3];
        cfg.choose = [Choose::Fifo, Choose::DepthFirst, Choose::Random][seed as usize % 3];
        let r = simulate(&g, &a, &cfg, &mut rng);

        let mut avail = std::collections::HashMap::new();
        for e in &r.execs {
            avail.insert((e.node, e.device), e.end);
        }
        for t in &r.transfers {
            avail.insert((t.node, t.to), t.end);
        }
        for e in &r.execs {
            for &p in &g.preds[e.node] {
                if g.preds[p].is_empty() {
                    continue;
                }
                let at = avail
                    .get(&(p, e.device))
                    .unwrap_or_else(|| panic!("seed {seed}: missing input {p}"));
                assert!(*at <= e.start + 1e-9, "seed {seed}: dep violated");
            }
        }
        // every non-entry node executed exactly once
        let non_entry = (0..g.n()).filter(|&v| !g.preds[v].is_empty()).count();
        assert_eq!(r.execs.len(), non_entry, "seed {seed}");
    }
}

/// Work-conserving lower/upper bounds hold: makespan is at least the
/// max single-vertex time and at least total-work/devices; and at most
/// the fully-serialized time plus all transfers.
#[test]
fn prop_sim_makespan_bounds() {
    for seed in 0..20u64 {
        let g = random_graph(seed + 100);
        let mut rng = Rng::new(seed);
        let nd = 2 + rng.below(3);
        let topo = doppler::eval::restrict(&DeviceTopology::p100x4(), nd);
        let a = random_valid_assignment(&g, nd, &mut rng);
        let cfg = SimConfig::deterministic(topo.clone());
        let r = simulate(&g, &a, &cfg, &mut rng);

        let total_work: f64 = g
            .nodes
            .iter()
            .filter(|n| !g.preds[n.id].is_empty())
            .map(|n| topo.exec_time(n, 0))
            .sum();
        let max_node = g
            .nodes
            .iter()
            .filter(|n| !g.preds[n.id].is_empty())
            .map(|n| topo.exec_time(n, 0))
            .fold(0.0, f64::max);
        assert!(r.makespan >= max_node - 1e-12, "seed {seed}");
        assert!(r.makespan >= total_work / nd as f64 - 1e-9, "seed {seed}");

        let transfers_ub: f64 = g
            .edges
            .iter()
            .map(|&(p, _)| topo.ref_transfer_time(g.nodes[p].out_bytes()))
            .sum();
        assert!(
            r.makespan <= total_work + transfers_ub + 1e-9,
            "seed {seed}: {} > {}",
            r.makespan,
            total_work + transfers_ub
        );
    }
}

/// The WC scheduler never loses to the bulk-synchronous executor on the
/// same assignment (zero jitter) — Table 1's premise, universally.
#[test]
fn prop_wc_dominates_bulksync() {
    for seed in 0..15u64 {
        let g = random_graph(seed + 300);
        let mut rng = Rng::new(seed);
        let topo = DeviceTopology::p100x4();
        let a = random_valid_assignment(&g, 4, &mut rng);
        let bs = bulksync_exec(&g, &a, &topo).makespan;
        let cfg = SimConfig::deterministic(topo);
        let wc = simulate(&g, &a, &cfg, &mut rng).makespan;
        assert!(wc <= bs * 1.0001, "seed {seed}: wc={wc} bs={bs}");
    }
}

/// Identical seeds give identical simulations; different jitter seeds
/// give different (but bounded-ratio) makespans.
#[test]
fn prop_sim_determinism_and_jitter() {
    for seed in 0..10u64 {
        let g = random_graph(seed + 500);
        let mut rng = Rng::new(seed);
        let a = random_valid_assignment(&g, 4, &mut rng);
        let cfg = SimConfig::new(DeviceTopology::p100x4());
        let m1 = simulate(&g, &a, &cfg, &mut Rng::new(seed)).makespan;
        let m2 = simulate(&g, &a, &cfg, &mut Rng::new(seed)).makespan;
        assert_eq!(m1, m2, "seed {seed}: nondeterministic");
        let m3 = simulate(&g, &a, &cfg, &mut Rng::new(seed + 1)).makespan;
        let ratio = m1.max(m3) / m1.min(m3);
        assert!(ratio < 2.0, "seed {seed}: jitter ratio {ratio} implausible");
    }
}

/// Every heuristic produces a valid assignment on every workload at
/// every device count, and candidate-set traversal covers the graph.
#[test]
fn prop_heuristics_always_valid() {
    for name in WORKLOADS {
        let g = by_name(name, Scale::Tiny);
        for nd in [1usize, 2, 4, 8] {
            let topo = doppler::eval::restrict(&DeviceTopology::v100x8(), nd);
            let feats = static_features(&g, &topo, 1.0);
            let mut rng = Rng::new(nd as u64);
            let cp = critical_path_once(&g, &topo, &feats, &mut rng, 0.2);
            check_assignment(&g, &cp, nd).unwrap();
            let eo = enumerative_optimizer(&g, &topo, &mut rng);
            check_assignment(&g, &eo, nd).unwrap();
            let rr = round_robin(&g, nd);
            check_assignment(&g, &rr, nd).unwrap();
        }
    }
}

/// AssignState candidate evolution: every node becomes a candidate
/// exactly once, in dependency order, regardless of placement choices.
#[test]
fn prop_candidate_set_complete_traversal() {
    for seed in 0..15u64 {
        let g = random_graph(seed + 700);
        let topo = DeviceTopology::p100x4();
        let mut st = AssignState::new(&g, &topo);
        let mut rng = Rng::new(seed);
        let mut seen = vec![false; g.n()];
        while !st.done() {
            let v = *rng.choose(&st.candidates);
            assert!(!seen[v], "seed {seed}: node {v} candidate twice");
            for &p in &g.preds[v] {
                assert!(seen[p], "seed {seed}: {v} before pred {p}");
            }
            seen[v] = true;
            st.place(v, rng.below(4));
        }
        assert!(seen.iter().all(|&s| s), "seed {seed}: incomplete");
    }
}

/// Memory enforcement only ever slows things down, never changes what
/// executes; spill time is nonnegative and zero with infinite memory.
#[test]
fn prop_memory_mode_monotone() {
    for seed in 0..8u64 {
        let g = by_name(WORKLOADS[seed as usize % 4], Scale::Tiny);
        let mut rng = Rng::new(seed);
        let a = random_valid_assignment(&g, 4, &mut rng);

        let mut unlimited = SimConfig::deterministic(DeviceTopology::p100x4());
        unlimited.enforce_memory = true; // infinite capacity: no spills
        let r0 = simulate(&g, &a, &unlimited, &mut rng);
        assert_eq!(r0.spill_time, 0.0, "seed {seed}");

        let mut tight = SimConfig::deterministic(DeviceTopology::p100x4_restricted(
            g.total_edge_bytes(),
            0.05,
        ));
        tight.enforce_memory = true;
        let r1 = simulate(&g, &a, &tight, &mut rng);
        assert!(r1.spill_time >= 0.0);
        assert!(
            r1.makespan >= r0.makespan - 1e-9,
            "seed {seed}: memory pressure sped things up"
        );
        assert_eq!(r0.execs.len(), r1.execs.len(), "seed {seed}");
    }
}

/// Static features are scale-covariant: doubling all tensor dims must
/// not change which node has the largest b-level (topology-determined).
#[test]
fn prop_feature_ordering_scale_invariant() {
    let topo = DeviceTopology::p100x4();
    for name in ["chainmm", "ffnn"] {
        let small = by_name(name, Scale::Tiny);
        let big = by_name(name, Scale::Small);
        let fs = static_features(&small, &topo, 1.0);
        let fb = static_features(&big, &topo, 1.0);
        let argmax = |xs: &[f64]| {
            xs.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        };
        // same topology => same critical-path endpoint family; compare
        // the node *kind* at the argmax rather than the exact id
        let ks = small.nodes[argmax(&fs.b_level)].kind;
        let kb = big.nodes[argmax(&fb.b_level)].kind;
        assert_eq!(ks.tag(), kb.tag(), "{name}: critical path moved between op kinds");
    }
}

/// Work conservation, checked from the trace: no execution unit idles
/// while it has a task whose inputs are present, and no channel idles
/// while a transfer is waiting on it. Concretely, every event starts no
/// later than the moment its resource was last free AND its dependencies
/// were satisfied — any later start is an idle-while-ready violation of
/// Algorithm 1's work-conserving guarantee.
#[test]
fn prop_work_conservation_no_idle_while_ready() {
    for seed in 0..20u64 {
        let g = random_graph(seed + 1100);
        let mut rng = Rng::new(seed ^ 0x77);
        let nd = 2 + rng.below(7);
        let a = random_valid_assignment(&g, nd, &mut rng);
        let mut cfg = SimConfig::new(doppler::eval::restrict(&DeviceTopology::v100x8(), nd));
        // decorrelated indices: every (jitter, choose) pair occurs
        cfg.jitter_sigma = [0.0, 0.1, 0.25][seed as usize % 3];
        cfg.choose = [Choose::Fifo, Choose::DepthFirst, Choose::Random][(seed as usize / 3) % 3];
        let r = simulate(&g, &a, &cfg, &mut rng);

        // availability time of node v's output on device d
        let mut avail = std::collections::HashMap::new();
        for e in &r.execs {
            avail.insert((e.node, e.device), e.end);
        }
        for t in &r.transfers {
            avail.insert((t.node, t.to), t.end);
        }
        let ready_on = |v: usize, d: usize| -> f64 {
            g.preds[v]
                .iter()
                .filter(|&&p| !g.preds[p].is_empty())
                .map(|&p| *avail.get(&(p, d)).expect("dependency never arrived"))
                .fold(0.0f64, f64::max)
        };

        // execution units: walk each device's exec timeline in order
        let mut by_dev: Vec<Vec<&doppler::sim::ExecEvent>> = vec![Vec::new(); nd];
        for e in &r.execs {
            by_dev[e.device].push(e);
        }
        for dev in by_dev.iter_mut() {
            dev.sort_by(|x, y| x.start.partial_cmp(&y.start).unwrap());
            let mut free_at = 0.0f64;
            for e in dev.iter() {
                let ready = ready_on(e.node, e.device);
                let must_start_by = free_at.max(ready);
                assert!(
                    e.start <= must_start_by + 1e-9,
                    "seed {seed}: device {} idled {:.3e}s while node {} was ready \
                     (start {:.6e}, free {:.6e}, ready {:.6e})",
                    e.device,
                    e.start - must_start_by,
                    e.node,
                    e.start,
                    free_at,
                    ready
                );
                free_at = e.end;
            }
        }

        // channels: a transfer is ready the moment its producer executed
        let mut by_chan: Vec<Vec<&doppler::sim::TransferEvent>> = vec![Vec::new(); nd * nd];
        for t in &r.transfers {
            by_chan[t.from * nd + t.to].push(t);
        }
        for chan in by_chan.iter_mut() {
            chan.sort_by(|x, y| x.start.partial_cmp(&y.start).unwrap());
            let mut free_at = 0.0f64;
            for t in chan.iter() {
                let produced = *avail
                    .get(&(t.node, t.from))
                    .expect("transferred a result that never executed");
                let must_start_by = free_at.max(produced);
                assert!(
                    t.start <= must_start_by + 1e-9,
                    "seed {seed}: channel {}->{} idled while node {}'s result waited",
                    t.from,
                    t.to,
                    t.node
                );
                free_at = t.end;
            }
        }
    }
}

fn assert_same_trace(x: &SimResult, y: &SimResult, ctx: &str) {
    assert_eq!(x.makespan, y.makespan, "{ctx}: makespan");
    assert_eq!(x.bytes_moved, y.bytes_moved, "{ctx}: bytes_moved");
    assert_eq!(x.spill_time, y.spill_time, "{ctx}: spill_time");
    assert_eq!(x.execs.len(), y.execs.len(), "{ctx}: exec count");
    for (i, (a, b)) in x.execs.iter().zip(&y.execs).enumerate() {
        assert_eq!(
            (a.node, a.device, a.start, a.end),
            (b.node, b.device, b.start, b.end),
            "{ctx}: exec event {i}"
        );
    }
    assert_eq!(x.transfers.len(), y.transfers.len(), "{ctx}: transfer count");
    for (i, (a, b)) in x.transfers.iter().zip(&y.transfers).enumerate() {
        assert_eq!(
            (a.node, a.from, a.to, a.start, a.end),
            (b.node, b.from, b.to, b.start, b.end),
            "{ctx}: transfer event {i}"
        );
    }
}

/// Engine equivalence: the incremental ready-set simulator is
/// **bitwise-identical** to the reference full-rescan engine —
/// makespan, spill_time, bytes_moved, and every exec/transfer event —
/// across random graphs, assignments, device counts, jitter levels,
/// and all three ChooseTask strategies. This is the contract that lets
/// `Engine::Incremental` be the production default while the reference
/// loop stays the semantics oracle (DESIGN.md §10).
#[test]
fn prop_sim_engines_bitwise_identical() {
    for seed in 0..30u64 {
        let g = random_graph(seed + 1700);
        let mut rng = Rng::new(seed ^ 0x1C0);
        let nd = 2 + rng.below(7);
        let a = random_valid_assignment(&g, nd, &mut rng);
        let mut cfg = SimConfig::new(doppler::eval::restrict(&DeviceTopology::v100x8(), nd));
        cfg.jitter_sigma = [0.0, 0.07, 0.25][seed as usize % 3];
        cfg.choose = [Choose::Fifo, Choose::DepthFirst, Choose::Random][(seed as usize / 3) % 3];
        let ctx = format!(
            "seed {seed} nd {nd} choose {:?} jitter {}",
            cfg.choose, cfg.jitter_sigma
        );

        let inc = simulate(
            &g,
            &a,
            &cfg.clone().with_engine(Engine::Incremental),
            &mut Rng::new(seed * 31),
        );
        let refr = simulate(
            &g,
            &a,
            &cfg.clone().with_engine(Engine::Reference),
            &mut Rng::new(seed * 31),
        );
        assert_same_trace(&inc, &refr, &ctx);

        // memory mode: spill penalties stretch durations and reorder
        // completions, so queue updates are exercised under pressure too
        let mut mem_cfg = cfg.clone();
        mem_cfg.topology.mem_capacity =
            vec![g.total_edge_bytes() * 0.05 / nd as f64; nd];
        mem_cfg.enforce_memory = true;
        let inc_m = simulate(
            &g,
            &a,
            &mem_cfg.clone().with_engine(Engine::Incremental),
            &mut Rng::new(seed * 31 + 7),
        );
        let ref_m = simulate(
            &g,
            &a,
            &mem_cfg.with_engine(Engine::Reference),
            &mut Rng::new(seed * 31 + 7),
        );
        assert_same_trace(&inc_m, &ref_m, &format!("{ctx} (memory)"));
    }
}

/// Parallel-vs-serial determinism: the rollout engine produces
/// bit-identical rewards AND traces at any worker count, for randomized
/// seeds, graphs, jitter levels, and device counts — the contract that
/// makes `--rollout-threads` a pure wall-clock knob.
#[test]
fn prop_rollout_parallel_matches_serial() {
    for seed in 0..12u64 {
        let g = random_graph(seed + 1300);
        let mut rng = Rng::new(seed ^ 0x5151);
        let nd = 2 + rng.below(7);
        let a = random_valid_assignment(&g, nd, &mut rng);
        let mut cfg = SimConfig::new(doppler::eval::restrict(&DeviceTopology::v100x8(), nd));
        cfg.jitter_sigma = [0.05, 0.15, 0.3][seed as usize % 3];
        let reps = 1 + (seed as usize % 4);

        // replicate traces: serial reference vs every worker count
        let serial =
            rollout::simulate_replicates(&g, &a, &cfg, &mut Rng::new(seed), reps, 1).unwrap();
        for threads in [2usize, 4, 8] {
            let par = rollout::simulate_replicates(&g, &a, &cfg, &mut Rng::new(seed), reps, threads)
                .unwrap();
            assert_eq!(serial.len(), par.len());
            for (r, (x, y)) in serial.iter().zip(&par).enumerate() {
                assert_same_trace(x, y, &format!("seed {seed} threads {threads} rep {r}"));
            }
        }

        // scalar rewards: rollout::mean_exec_time == sim::mean_exec_time
        let reference = doppler::sim::mean_exec_time(&g, &a, &cfg, &mut Rng::new(seed + 9), reps);
        for threads in [1usize, 2, 4, 8] {
            let got = rollout::mean_exec_time(&g, &a, &cfg, &mut Rng::new(seed + 9), reps, threads)
                .unwrap();
            assert_eq!(got, reference, "seed {seed} threads {threads}: reward drifted");
        }

        // batched Stage II rewards over several episode assignments
        let assignments: Vec<Assignment> = (0..4)
            .map(|e| random_valid_assignment(&g, nd, &mut Rng::new(seed * 100 + e)))
            .collect();
        let serial_r =
            rollout::episode_rewards(&g, &assignments, &cfg, &mut Rng::new(seed), reps, 1)
                .unwrap();
        for threads in [2usize, 8] {
            let par_r = rollout::episode_rewards(
                &g,
                &assignments,
                &cfg,
                &mut Rng::new(seed),
                reps,
                threads,
            )
            .unwrap();
            assert_eq!(serial_r, par_r, "seed {seed} threads {threads}: batch rewards");
        }
    }
}

/// Transfer accounting: bytes_moved equals the sum of producer sizes of
/// actually-transferred results, and no transfer happens twice for the
/// same (node, destination).
#[test]
fn prop_transfer_accounting() {
    for seed in 0..10u64 {
        let g = random_graph(seed + 900);
        let mut rng = Rng::new(seed);
        let a = random_valid_assignment(&g, 4, &mut rng);
        let cfg = SimConfig::deterministic(DeviceTopology::p100x4());
        let r = simulate(&g, &a, &cfg, &mut rng);
        let mut seen = std::collections::HashSet::new();
        let mut total = 0.0;
        for t in &r.transfers {
            assert!(seen.insert((t.node, t.to)), "seed {seed}: duplicate transfer");
            assert_ne!(t.from, t.to, "seed {seed}: self transfer");
            total += g.nodes[t.node].out_bytes();
        }
        assert!((total - r.bytes_moved).abs() < 1e-6, "seed {seed}");
    }
}

/// Parallel whole-episode generation (native backend) is bit-identical
/// at any thread count: same assignments, same trajectories, same
/// ε-greedy draws — the rollout determinism contract extended to the
/// policies themselves (ISSUE 3). Also pins that reusing one scratch
/// across sequential episodes changes nothing.
#[test]
fn prop_episode_generation_bitwise_identical_across_threads() {
    use doppler::policy::{
        run_episode_with, EpisodeCfg, EpisodeScratch, GraphEncoding, Method, NativePolicy,
        PolicyBackend,
    };

    let nets = NativePolicy::builtin();
    for seed in 0..4u64 {
        let g = synthetic_layered(60 + 20 * seed as usize, seed);
        let topo = doppler::eval::restrict(&DeviceTopology::v100x8(), 4);
        let feats = static_features(&g, &topo, 1.0);
        let variant = nets.variant_for_graph(g.n(), g.m()).unwrap();
        let enc = GraphEncoding::build(&g, &feats, nets.manifest(), &variant).unwrap();
        let params = PolicyBackend::init_params(&nets).unwrap();
        let cfg = EpisodeCfg {
            method: [Method::Doppler, Method::Gdp][seed as usize % 2],
            epsilon: 0.3, // exploration active: RNG draws must line up too
            n_devices: 4,
            per_step_encode: false,
        };

        let episodes = 6;
        let reference = {
            let mut base = Rng::new(100 + seed);
            rollout::generate_episodes(
                &nets, &enc, &g, &topo, &feats, &params, &cfg, &mut base, episodes, 1,
            )
            .unwrap()
        };
        // serial reference equals per-episode scratch-reused loop
        {
            let mut base = Rng::new(100 + seed);
            let mut scratch = EpisodeScratch::new();
            for (i, want) in reference.iter().enumerate() {
                let mut rng = base.fork(i as u64);
                let got = run_episode_with(
                    &nets, &enc, &g, &topo, &feats, &params, &cfg, &mut rng, &mut scratch,
                )
                .unwrap();
                assert_eq!(got.assignment, want.assignment, "seed {seed} ep {i}: scratch reuse");
                assert_eq!(
                    got.trajectory.plc_actions, want.trajectory.plc_actions,
                    "seed {seed} ep {i}: scratch reuse (plc)"
                );
            }
        }
        for threads in [2usize, 4, 8] {
            let mut base = Rng::new(100 + seed);
            let got = rollout::generate_episodes(
                &nets, &enc, &g, &topo, &feats, &params, &cfg, &mut base, episodes, threads,
            )
            .unwrap();
            assert_eq!(got.len(), reference.len());
            for (i, (a, b)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(a.assignment, b.assignment, "seed {seed} threads {threads} ep {i}");
                assert_eq!(
                    a.trajectory.sel_actions, b.trajectory.sel_actions,
                    "seed {seed} threads {threads} ep {i}: sel"
                );
                assert_eq!(
                    a.trajectory.plc_actions, b.trajectory.plc_actions,
                    "seed {seed} threads {threads} ep {i}: plc"
                );
                assert_eq!(
                    a.trajectory.xd_steps, b.trajectory.xd_steps,
                    "seed {seed} threads {threads} ep {i}: xd"
                );
                assert_eq!(
                    a.trajectory.cand_masks, b.trajectory.cand_masks,
                    "seed {seed} threads {threads} ep {i}: cand"
                );
            }
        }
    }
}

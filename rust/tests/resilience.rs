//! Fault-injection property tests (DESIGN.md §15): the retry-determinism
//! contract end to end.
//!
//! - A fault-injected run whose retry budgets survive is **bit-identical**
//!   to the fault-free run, at every worker count (retries re-run with a
//!   fresh clone of the item's original forked RNG stream).
//! - A run whose budget is exhausted fails with the typed
//!   [`RolloutError`] — recoverable through the `anyhow` shim with
//!   `downcast_ref` — instead of aborting the process.
//! - Real panics in worker items are isolated, retried, and counted.
//! - Stage III degrades to simulator rewards when the engine stays
//!   unavailable through its budget (`engine_fallbacks`), instead of
//!   tearing the run down.
//!
//! The fault plan and its event counters are process-global, so every
//! test here serializes on one mutex and clears the plan on drop. Tests
//! that need a quiet panic storm swap in a no-op panic hook while the
//! lock is held.

use std::sync::{Arc, Mutex};

use doppler::graph::workloads::{chainmm, Scale};
use doppler::graph::Assignment;
use doppler::heuristics::random_assignment;
use doppler::policy::{Method, NativePolicy};
use doppler::rollout::{self, RolloutError};
use doppler::runtime::resilience::{self, FaultPlan};
use doppler::sim::topology::DeviceTopology;
use doppler::sim::SimConfig;
use doppler::train::{Stages, TrainConfig, Trainer};
use doppler::util::rng::Rng;

/// Serializes every test in this binary: the fault plan, the injection
/// epoch, and the stats counters are process-global.
static LOCK: Mutex<()> = Mutex::new(());

/// Holds the test lock, and clears the global plan + counters on drop —
/// even when the test body panics — so one failure cannot cascade.
struct PlanGuard<'a> {
    _lock: std::sync::MutexGuard<'a, ()>,
}

impl<'a> PlanGuard<'a> {
    fn acquire() -> PlanGuard<'a> {
        let lock = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        resilience::set_plan(None);
        resilience::reset_stats();
        PlanGuard { _lock: lock }
    }
}

impl Drop for PlanGuard<'_> {
    fn drop(&mut self) {
        resilience::set_plan(None);
        resilience::reset_stats();
    }
}

fn install(spec: &str) -> Arc<FaultPlan> {
    let plan = Arc::new(FaultPlan::parse(spec).unwrap());
    resilience::set_plan(Some(plan.clone()));
    plan
}

fn test_fixture() -> (doppler::graph::Graph, SimConfig, Vec<Assignment>) {
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let cfg = SimConfig::new(topo);
    let mut rng = Rng::new(77);
    let assignments: Vec<Assignment> = (0..6)
        .map(|_| random_assignment(&g, 4, &mut rng))
        .collect();
    (g, cfg, assignments)
}

/// Core contract: when the retry budget survives the injected faults,
/// rewards are bit-identical to the fault-free golden run — at 1/2/4/8
/// worker threads. Injection rates < 1 with a generous budget make
/// survival overwhelmingly likely, but the schedule is deterministic per
/// plan seed, so we scan a few seeds and require that at least one
/// survives (each surviving run must match the golden bits exactly).
#[test]
fn surviving_fault_runs_are_bit_identical_to_fault_free() {
    let _guard = PlanGuard::acquire();
    let (g, cfg, assignments) = test_fixture();
    let reps = 3;

    // fault-free golden (no plan active)
    let golden =
        rollout::episode_rewards(&g, &assignments, &cfg, &mut Rng::new(5), reps, 1).unwrap();

    let mut survived = 0usize;
    for plan_seed in [1u64, 2, 3] {
        let spec = format!("rollout=0.3,retries=8,seed={plan_seed}");
        for threads in [1usize, 2, 4, 8] {
            // reinstall per run: set_plan resets the injection epoch, so
            // every run replays the same (seed-keyed) failure schedule
            install(&spec);
            let got = rollout::episode_rewards(
                &g,
                &assignments,
                &cfg,
                &mut Rng::new(5),
                reps,
                threads,
            );
            resilience::set_plan(None);
            match got {
                Ok(rewards) => {
                    survived += 1;
                    assert_eq!(
                        rewards, golden,
                        "plan seed {plan_seed}, {threads} threads: surviving \
                         fault run drifted from the fault-free golden"
                    );
                }
                Err(e) => {
                    // budget exhausted for this schedule: must be the
                    // typed error, and deterministic across threads too —
                    // but bit-identity is only claimed for Ok runs
                    assert!(!e.failures.is_empty(), "empty RolloutError");
                }
            }
        }
    }
    assert!(
        survived > 0,
        "no fault schedule survived its retry budget across 3 plan seeds"
    );
    let stats = resilience::stats();
    assert!(stats.injected > 0, "rate-0.3 plan never injected a fault");
}

/// Rate 1.0 deterministically exhausts the budget: the typed
/// [`RolloutError`] surfaces (not a process abort), carries per-item
/// attempt counts equal to the budget, and round-trips through the
/// `anyhow` shim via `downcast_ref`.
#[test]
fn exhausted_budget_yields_typed_rollout_error() {
    let _guard = PlanGuard::acquire();
    let (g, cfg, assignments) = test_fixture();
    install("rollout=1.0,retries=3,seed=0");

    // direct typed error from the rollout layer
    let err = rollout::episode_rewards(&g, &assignments, &cfg, &mut Rng::new(5), 2, 4)
        .expect_err("rate-1.0 plan must exhaust every budget");
    assert_eq!(err.site, "rollout.sim");
    assert_eq!(err.total, assignments.len() * 2);
    assert_eq!(err.failures.len(), err.total, "every item must fail at rate 1.0");
    for f in &err.failures {
        assert_eq!(f.attempts, 3, "attempts must equal the retry budget");
        assert_eq!(f.injected, 3, "all failures here are injected");
    }
    // canonical index order
    let idx: Vec<usize> = err.failures.iter().map(|f| f.index).collect();
    let mut sorted = idx.clone();
    sorted.sort_unstable();
    assert_eq!(idx, sorted);

    // the payload survives `?` through the anyhow shim
    let through_anyhow = || -> anyhow::Result<f64> {
        Ok(rollout::mean_exec_time(&g, &assignments[0], &cfg, &mut Rng::new(5), 2, 2)?)
    };
    let e = through_anyhow().expect_err("rate-1.0 plan must fail mean_exec_time");
    let typed = e
        .downcast_ref::<RolloutError>()
        .expect("RolloutError payload lost through the anyhow shim");
    assert_eq!(typed.site, "rollout.sim");
    assert!(resilience::stats().exhausted > 0);
}

/// Real worker panics (no plan involved) are isolated by `catch_unwind`,
/// retried with the default budget, and the run survives a transient
/// panic bit-identically; a *persistent* panic exhausts the default
/// budget and surfaces as a structured error naming the item.
#[test]
fn worker_panics_are_isolated_and_retried() {
    let _guard = PlanGuard::acquire();
    // silence the panic backtraces this test deliberately provokes
    let prev_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let result = std::panic::catch_unwind(|| {
        let expected: Vec<usize> = (0..16).map(|i| i * i).collect();

        // transient: item 5 panics on its first attempt only
        let first = std::sync::atomic::AtomicBool::new(true);
        let got = rollout::parallel_map(4, 16, |i| {
            if i == 5 && first.swap(false, std::sync::atomic::Ordering::SeqCst) {
                panic!("transient worker failure");
            }
            i * i
        })
        .expect("a transient panic must be retried, not fatal");
        assert_eq!(got, expected);
        let stats = resilience::stats();
        assert!(stats.panics >= 1, "the panic was not counted");
        assert!(stats.retried_ok >= 1, "the retry success was not counted");

        // persistent: item 5 panics on every attempt -> typed error
        let err = rollout::parallel_map(4, 16, |i| {
            if i == 5 {
                panic!("persistent worker failure");
            }
            i * i
        })
        .expect_err("a persistent panic must exhaust the budget");
        assert_eq!(err.failures.len(), 1);
        assert_eq!(err.failures[0].index, 5);
        assert_eq!(err.failures[0].attempts, resilience::DEFAULT_MAX_ATTEMPTS);
        assert_eq!(err.failures[0].injected, 0);
        assert!(err.failures[0].last_error.contains("persistent worker failure"));
    });
    std::panic::set_hook(prev_hook);
    if let Err(p) = result {
        std::panic::resume_unwind(p);
    }
}

/// Stage II training under a surviving fault plan produces bit-identical
/// parameters and history to the fault-free trainer, at 1 and 4 rollout
/// threads (the end-to-end version of the rollout-level contract).
#[test]
fn fault_injected_training_matches_fault_free_when_budget_survives() {
    let _guard = PlanGuard::acquire();
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let run = |threads: usize| {
        let nets = NativePolicy::builtin();
        let mut cfg = TrainConfig::new(Method::Doppler, topo.clone(), 4);
        cfg.seed = 9;
        cfg.episode_batch = 4;
        cfg.rollout.threads = threads;
        cfg.rollout.sim_reps = 2;
        let mut trainer = Trainer::new(&nets, &g, topo.clone(), cfg).unwrap();
        trainer.stage2_sim(12)?;
        Ok::<_, anyhow::Error>((
            trainer.params.clone(),
            trainer
                .history
                .iter()
                .map(|r| (r.exec_time, r.loss))
                .collect::<Vec<_>>(),
        ))
    };

    let golden = run(1).expect("fault-free training failed");

    let mut survived = 0usize;
    for plan_seed in [1u64, 2, 3] {
        let spec = format!("rollout=0.2,retries=8,seed={plan_seed}");
        for threads in [1usize, 4] {
            install(&spec);
            let got = run(threads);
            resilience::set_plan(None);
            if let Ok(got) = got {
                survived += 1;
                assert_eq!(
                    got, golden,
                    "plan seed {plan_seed}, {threads} threads: fault-injected \
                     training drifted from the fault-free golden"
                );
            }
        }
    }
    assert!(
        survived > 0,
        "no training fault schedule survived across 3 plan seeds"
    );
    assert!(resilience::stats().injected > 0);
}

/// Stage III with a permanently-dead engine (`engine.execute=1.0`) must
/// *degrade*, not abort: every episode takes the simulator-reward
/// fallback, the run completes, and the fallbacks are counted in the
/// result and the global stats.
#[test]
fn dead_engine_degrades_stage3_to_simulator_rewards() {
    let _guard = PlanGuard::acquire();
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let nets = NativePolicy::builtin();
    let mut cfg = TrainConfig::new(Method::Doppler, topo.clone(), 4);
    cfg.seed = 21;
    let trainer = Trainer::new(&nets, &g, topo.clone(), cfg).unwrap();
    let engine_cfg = doppler::engine::EngineConfig::new(topo);

    install("engine.execute=1.0,retries=2,seed=0");
    let result = trainer
        .run(
            Stages {
                imitation: 0,
                sim_rl: 0,
                real_rl: 3,
            },
            &engine_cfg,
        )
        .expect("a dead engine must degrade, not abort the run");
    resilience::set_plan(None);

    assert_eq!(result.history.len(), 3);
    assert!(result.history.iter().all(|r| r.stage == 3));
    assert!(result.history.iter().all(|r| r.exec_time.is_finite()));
    assert_eq!(
        result.engine_fallbacks, 3,
        "every episode should have fallen back to the simulator"
    );
    assert!(resilience::stats().engine_fallbacks >= 3);
    assert_eq!(result.anomalies, 0, "fallback rewards are finite, not anomalies");
}

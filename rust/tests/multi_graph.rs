//! Multi-graph transfer training invariants (ISSUE 4 / DESIGN.md §12).
//!
//! The shared parameter blob must be a pure function of
//! `(seed, workload set, budget, episode_batch)`:
//!
//! - **thread counts never leak** — episode generation fans out across
//!   the rollout pool but gradient reduction happens in canonical
//!   (round, workload, episode) order, so 1/2/4 threads produce
//!   bit-identical params;
//! - **member-list order never leaks** — `WorkloadSet` canonicalizes to
//!   name-sorted order and RNG streams are keyed by workload *name*, so
//!   permuting the manifest changes nothing.
//!
//! Runs entirely on the native backend: zero artifacts required.

use doppler::graph::workloads::Scale;
use doppler::policy::{Method, NativePolicy};
use doppler::train::multi::{MultiGraphTrainer, MultiTrainCfg, WorkloadSet};
use doppler::train::{Schedule, Stages, TrainConfig, UpdateMode};

/// Small multi-graph run on an already-built set; returns the shared
/// blob and the per-workload episode counts.
fn run_shared(set: &WorkloadSet, threads: usize, batch: usize) -> (Vec<f32>, Vec<usize>) {
    run_shared_mode(set, threads, batch, UpdateMode::Sequential)
}

/// [`run_shared`] with an explicit Stage II update mode.
fn run_shared_mode(
    set: &WorkloadSet,
    threads: usize,
    batch: usize,
    mode: UpdateMode,
) -> (Vec<f32>, Vec<usize>) {
    let nets = NativePolicy::builtin();
    let first = &set.train[0];
    let mut base = TrainConfig::new(
        Method::Doppler,
        first.build_topology().unwrap(),
        first.n_devices,
    );
    base.seed = 7;
    base.episode_batch = batch;
    base.update_mode = mode;
    base.rollout.threads = threads;
    base.rollout.sim_reps = 2;
    base.lr = Schedule {
        start: 1e-3,
        end: 1e-4,
    };
    base.epsilon = Schedule {
        start: 0.3,
        end: 0.05,
    };
    let stages = Stages {
        imitation: 4,
        sim_rl: 12,
        real_rl: 0,
    };
    let result = MultiGraphTrainer::new(&nets, set, MultiTrainCfg { base, stages })
        .run()
        .unwrap();
    let episodes = result.reports.iter().map(|r| r.episodes).collect();
    (result.params, episodes)
}

#[test]
fn shared_params_bit_identical_across_thread_counts() {
    let set = WorkloadSet::builtin("tiny").unwrap();
    let (p1, e1) = run_shared(&set, 1, 3);
    assert_eq!(e1.iter().sum::<usize>(), 16, "budget fully spent");
    for threads in [2usize, 4] {
        let (p, e) = run_shared(&set, threads, 3);
        assert_eq!(e, e1, "threads={threads}: episode split changed");
        assert_eq!(p, p1, "threads={threads}: thread count leaked into shared params");
    }
}

#[test]
fn shared_params_invariant_under_workload_order_permutation() {
    let a = WorkloadSet::from_names(
        "a",
        &["chainmm", "synthetic-40", "synthetic-60"],
        &[],
        Scale::Tiny,
        "p100x4",
        4,
    )
    .unwrap();
    let b = WorkloadSet::from_names(
        "b",
        &["synthetic-60", "chainmm", "synthetic-40"],
        &[],
        Scale::Tiny,
        "p100x4",
        4,
    )
    .unwrap();
    // canonical order is identical regardless of input order ...
    let names = |s: &WorkloadSet| s.train.iter().map(|w| w.name.clone()).collect::<Vec<_>>();
    assert_eq!(names(&a), names(&b));
    // ... and so is the trained shared blob, bit for bit
    let (pa, _) = run_shared(&a, 2, 2);
    let (pb, _) = run_shared(&b, 2, 2);
    assert_eq!(pa, pb, "workload-list permutation leaked into shared params");
}

#[test]
fn accumulate_mode_shared_params_deterministic() {
    // the accumulate update path (ISSUE 5 / DESIGN.md §13) must honor
    // the same multi-graph contract: bit-identical shared params at any
    // thread count and under member-list permutation — and actually
    // differ from sequential mode (one optimizer step per chunk)
    let set = WorkloadSet::builtin("tiny").unwrap();
    let (p1, e1) = run_shared_mode(&set, 1, 3, UpdateMode::Accumulate);
    assert_eq!(e1.iter().sum::<usize>(), 16, "budget fully spent");
    for threads in [2usize, 4] {
        let (p, e) = run_shared_mode(&set, threads, 3, UpdateMode::Accumulate);
        assert_eq!(e, e1, "threads={threads}: episode split changed");
        assert_eq!(p, p1, "threads={threads}: thread count leaked into accumulated params");
    }
    let permuted = WorkloadSet::from_names(
        "perm",
        &["synthetic-60", "chainmm", "synthetic-40"],
        &[],
        Scale::Tiny,
        "p100x4",
        4,
    )
    .unwrap();
    let ordered = WorkloadSet::from_names(
        "ord",
        &["chainmm", "synthetic-40", "synthetic-60"],
        &[],
        Scale::Tiny,
        "p100x4",
        4,
    )
    .unwrap();
    let (pp, _) = run_shared_mode(&permuted, 2, 2, UpdateMode::Accumulate);
    let (po, _) = run_shared_mode(&ordered, 2, 2, UpdateMode::Accumulate);
    assert_eq!(pp, po, "member permutation leaked into accumulated shared params");
    // different numerics from sequential on the same budget
    let (ps, _) = run_shared_mode(&set, 2, 3, UpdateMode::Sequential);
    assert_ne!(ps, p1, "accumulate chunks should step the optimizer once per batch");
}

#[test]
fn builtin_suites_resolve_and_are_canonical() {
    for name in WorkloadSet::BUILTIN_SUITES {
        let s = WorkloadSet::builtin(name).unwrap();
        assert!(s.train.len() >= 3, "{name}: needs >= 3 train workloads");
        assert!(!s.holdout.is_empty(), "{name}: needs a holdout target");
        let names: Vec<_> = s.train.iter().map(|w| w.name.clone()).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted, "{name}: members not in canonical order");
        for w in s.train.iter().chain(&s.holdout) {
            let g = w.build_graph().unwrap_or_else(|e| panic!("{name}/{}: {e}", w.name));
            assert!(g.n() > 10, "{name}/{}", w.name);
            let t = w.build_topology().unwrap();
            assert_eq!(t.n(), w.n_devices, "{name}/{}", w.name);
        }
        // the whole point of the split: the holdout is unseen in training
        for h in &s.holdout {
            assert!(
                s.train.iter().all(|w| w.name != h.name),
                "{name}: holdout '{}' leaked into train",
                h.name
            );
        }
    }
    assert!(WorkloadSet::builtin("nope").is_err());
}

#[test]
fn workload_set_manifest_roundtrip() {
    let dir = std::env::temp_dir().join("doppler_test_wset");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("workloads.json");
    std::fs::write(
        &path,
        r#"{
          "name": "custom", "topology": "p100x4", "devices": 4,
          "train": [
            {"workload": "ffnn", "weight": 2.0},
            {"workload": "chainmm", "scale": "tiny"},
            {"workload": "synthetic-80"}
          ],
          "holdout": [{"workload": "llama-block", "scale": "small"}]
        }"#,
    )
    .unwrap();
    let s = WorkloadSet::load(&path).unwrap();
    assert_eq!(s.name, "custom");
    assert_eq!(s.train.len(), 3);
    // canonical (name-sorted) order with per-entry scale/weight applied
    assert_eq!(s.train[0].name, "chainmm");
    assert_eq!(s.train[0].scale, Scale::Tiny);
    assert_eq!(s.train[1].name, "ffnn");
    assert_eq!(s.train[1].scale, Scale::Full);
    assert_eq!(s.train[1].weight, 2.0);
    assert_eq!(s.train[2].name, "synthetic-80");
    assert_eq!(s.holdout.len(), 1);
    assert_eq!(s.holdout[0].name, "llama-block");
    assert_eq!(s.holdout[0].scale, Scale::Small);
    // a manifest with an unknown scale fails to resolve
    std::fs::write(
        &path,
        r#"{"train": [{"workload": "ffnn", "scale": "huge"}]}"#,
    )
    .unwrap();
    assert!(WorkloadSet::load(&path).is_err());
}

#[test]
fn multi_graph_requires_sync_backend_and_no_stage3() {
    let nets = NativePolicy::builtin();
    let set = WorkloadSet::builtin("tiny").unwrap();
    let first = &set.train[0];
    let base = TrainConfig::new(
        Method::Doppler,
        first.build_topology().unwrap(),
        first.n_devices,
    );
    // stage III in the multi budget is a config error
    let bad = MultiTrainCfg {
        base,
        stages: Stages {
            imitation: 1,
            sim_rl: 1,
            real_rl: 1,
        },
    };
    assert!(MultiGraphTrainer::new(&nets, &set, bad).run().is_err());
}

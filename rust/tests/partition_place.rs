//! Partition-then-place pins (DESIGN.md §17; the CI `partition-pins`
//! step): the cut invariants every downstream guarantee rests on, the
//! refinement pinning contract, and the determinism pins — hierarchical
//! placement bit-identical at 1/2/4/8 worker threads, K=1 degenerating
//! bitwise to the flat path.

use doppler::graph::partition::{
    flat_place, hierarchical_place, partition, quotient_graph, refine_shard, PartitionCfg,
    PlacementCfg, PlacementMode,
};
use doppler::graph::workloads::{llama_block, synthetic_layered, Scale};
use doppler::graph::NodeId;
use doppler::heuristics::check_assignment;
use doppler::sim::topology::DeviceTopology;
use doppler::util::rng::Rng;

fn topo() -> DeviceTopology {
    DeviceTopology::p100x4()
}

fn hier_cfg(k: usize) -> PlacementCfg {
    PlacementCfg {
        mode: PlacementMode::Hierarchical,
        part: PartitionCfg { k, halo_depth: 1 },
        refine_rounds: 2,
        flat_rounds: 3,
    }
}

/// Shard interiors must partition the vertex set: every node in exactly
/// one interior, across workload families and shard counts.
#[test]
fn shard_cover_and_no_overlap() {
    for (g, k) in [
        (synthetic_layered(400, 11), 8),
        (synthetic_layered(257, 2), 5),
        (llama_block(Scale::Tiny), 3),
    ] {
        let p = partition(&g, &PartitionCfg { k, halo_depth: 1 });
        assert_eq!(p.k(), k, "{}", g.name);
        let mut owner = vec![usize::MAX; g.n()];
        for (si, sh) in p.shards.iter().enumerate() {
            for &v in &sh.interior {
                assert_eq!(owner[v], usize::MAX, "{}: node {v} in two interiors", g.name);
                owner[v] = si;
            }
        }
        assert!(
            owner.iter().all(|&o| o != usize::MAX),
            "{}: interiors must cover every node",
            g.name
        );
        assert_eq!(owner, p.shard_of, "{}: shard_of must mirror interiors", g.name);
    }
}

/// Shard index is monotone along every edge (the downset-growth
/// guarantee), so the quotient graph is a DAG by construction.
#[test]
fn quotient_is_acyclic() {
    let g = synthetic_layered(500, 23);
    let p = partition(&g, &PartitionCfg { k: 9, halo_depth: 1 });
    for &(u, v) in &g.edges {
        assert!(
            p.shard_of[u] <= p.shard_of[v],
            "edge {u}->{v}: shard {} -> {} goes backward",
            p.shard_of[u],
            p.shard_of[v]
        );
    }
    for &(u, v) in &p.cut_edges {
        assert!(p.shard_of[u] < p.shard_of[v], "cut edge {u}->{v} not forward");
    }
    let q = quotient_graph(&g, &p);
    assert!(q.topo_order().is_some(), "quotient has a cycle");
    assert_eq!(q.n(), p.k() + 1, "k super-nodes + the synthetic root");
}

/// With halo_depth >= 1 every neighbor of an interior node is inside
/// the shard subgraph — the refinement pass sees full local context.
#[test]
fn halo_closes_interior_neighborhoods() {
    let g = synthetic_layered(300, 5);
    for depth in [1usize, 2] {
        let p = partition(&g, &PartitionCfg { k: 6, halo_depth: depth });
        for (si, sh) in p.shards.iter().enumerate() {
            let inside = |v: NodeId| {
                sh.interior.binary_search(&v).is_ok() || sh.halo.binary_search(&v).is_ok()
            };
            for &v in &sh.interior {
                for &u in g.preds[v].iter().chain(g.succs[v].iter()) {
                    assert!(
                        inside(u),
                        "depth {depth}, shard {si}: neighbor {u} of interior {v} missing"
                    );
                }
            }
            for &h in &sh.halo {
                assert_ne!(p.shard_of[h], si, "halo node {h} owned by shard {si} itself");
            }
        }
    }
}

/// The PR-1 pool contract carried through placement: worker-thread
/// count is a pure wall-clock knob, the merged assignment is bitwise
/// identical at 1/2/4/8 threads.
#[test]
fn hierarchical_bit_identical_across_thread_counts() {
    let g = synthetic_layered(600, 17);
    let t = topo();
    let cfg = hier_cfg(10);
    let base = hierarchical_place(&g, &t, &cfg, 1, 99).unwrap();
    check_assignment(&g, &base, t.n()).unwrap();
    for threads in [2usize, 4, 8] {
        let a = hierarchical_place(&g, &t, &cfg, threads, 99).unwrap();
        assert_eq!(a, base, "thread count {threads} changed the assignment");
    }
}

/// K = 1 must short-circuit to the flat path, bit for bit: the quotient
/// of one shard is the graph itself, so there is nothing to refine.
#[test]
fn k1_degenerates_bitwise_to_flat() {
    let g = synthetic_layered(350, 31);
    let t = topo();
    let cfg = hier_cfg(1);
    for threads in [1usize, 4] {
        let hier = hierarchical_place(&g, &t, &cfg, threads, 5).unwrap();
        let flat = flat_place(&g, &t, 5, cfg.flat_rounds);
        assert_eq!(hier, flat, "K=1 at {threads} threads must equal flat");
    }
}

/// Refinement must never move halo context: the pins it reports match
/// the coarse expansion, and it only ever re-places interior nodes.
#[test]
fn refinement_respects_halo_pins() {
    let g = synthetic_layered(450, 13);
    let t = topo();
    let p = partition(&g, &PartitionCfg { k: 8, halo_depth: 1 });
    // a deliberately non-uniform coarse expansion so pins are distinguishable
    let coarse: Vec<usize> = (0..g.n()).map(|v| p.shard_of[v] % t.n()).collect();
    for si in 0..p.k() {
        let r = refine_shard(&g, &p, si, &coarse, &t, &mut Rng::new(77), 2);
        assert_eq!(r.shard, si);
        // pins echo the coarse devices of the halo nodes' owning shards
        assert_eq!(r.halo_pins.len(), p.shards[si].halo.len());
        for &(h, d) in &r.halo_pins {
            assert!(p.shards[si].halo.binary_search(&h).is_ok());
            assert_eq!(d, coarse[h], "halo node {h} pinned off its coarse device");
        }
        // refined set is exactly the interior — never a halo node
        let refined: Vec<NodeId> = r.interior.iter().map(|&(v, _)| v).collect();
        assert_eq!(refined, p.shards[si].interior);
        for &(_, d) in &r.interior {
            assert!(d < t.n(), "refined device out of range");
        }
    }
}

/// Same seed, same result; auto shard count places a valid assignment
/// on a graph far beyond the flat episode's comfort zone.
#[test]
fn deterministic_and_valid_at_scale() {
    let g = synthetic_layered(2_000, 7);
    let t = topo();
    let cfg = PlacementCfg {
        mode: PlacementMode::Hierarchical,
        part: PartitionCfg::default(), // k = 0 -> auto
        refine_rounds: 2,
        flat_rounds: 2,
    };
    let a1 = hierarchical_place(&g, &t, &cfg, 4, 3).unwrap();
    let a2 = hierarchical_place(&g, &t, &cfg, 4, 3).unwrap();
    assert_eq!(a1, a2, "same seed must reproduce bitwise");
    check_assignment(&g, &a1, t.n()).unwrap();
}

//! Integration: load real AOT artifacts via PJRT, run encode/sel/plc,
//! a full ASSIGN episode, and a train step. Requires `make artifacts`
//! (skips with a notice when artifacts/ is missing).

use doppler::features::static_features;
use doppler::graph::workloads::{chainmm, Scale};
use doppler::policy::{run_episode, EpisodeCfg, GraphEncoding, Method, OptState, PolicyNets};
use doppler::sim::topology::DeviceTopology;
use doppler::util::rng::Rng;

fn nets_or_skip() -> Option<PolicyNets> {
    match PolicyNets::load_default() {
        Ok(n) => Some(n),
        Err(e) => {
            eprintln!("SKIP runtime integration (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn episode_and_train_roundtrip() {
    let Some(nets) = nets_or_skip() else { return };
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let feats = static_features(&g, &topo, 1.0);
    let variant = nets.manifest.variant_for(g.n(), g.m()).unwrap().clone();
    let enc = GraphEncoding::build(&g, &feats, &nets.manifest, &variant).unwrap();

    let mut params = nets.init_params().unwrap();
    assert_eq!(params.len(), nets.manifest.param_count);

    // encode: finite, masked padding
    let hcat = nets.encode(&variant, &enc, &params).unwrap();
    assert_eq!(hcat.len(), variant.n * nets.manifest.sel_in);
    assert!(hcat.iter().all(|x| x.is_finite()));
    let pad = &hcat[g.n() * nets.manifest.sel_in..];
    assert!(pad.iter().all(|&x| x.abs() < 1e-6), "padding region not masked");

    // deterministic encode
    let hcat2 = nets.encode(&variant, &enc, &params).unwrap();
    assert_eq!(hcat, hcat2);

    // full episode for each method
    for method in [Method::Doppler, Method::Placeto, Method::Gdp] {
        let cfg = EpisodeCfg {
            method,
            epsilon: 0.2,
            n_devices: 4,
            per_step_encode: false,
        };
        let mut rng = Rng::new(7);
        let ep = run_episode(&nets, &enc, &g, &topo, &feats, &params, &cfg, &mut rng).unwrap();
        assert_eq!(ep.assignment.len(), g.n());
        assert!(ep.assignment.iter().all(|&d| d < 4));
        assert_eq!(ep.encode_calls, 1);
        let steps: f32 = ep.trajectory.step_mask.iter().sum();
        assert_eq!(steps as usize, g.n());

        // train step: loss finite, params move
        let mut opt = OptState::new(params.len());
        let dev_mask = doppler::policy::device_mask(nets.manifest.max_devices, 4);
        let p_before = params.clone();
        let (loss, ent) = nets
            .train(method, &variant, &enc, &mut params, &mut opt, &ep.trajectory,
                   &dev_mask, 1.0, 1e-3, 1e-2)
            .unwrap();
        assert!(loss.is_finite() && ent.is_finite(), "{method:?}: loss={loss} ent={ent}");
        assert!(ent >= 0.0);
        assert_ne!(params, p_before, "{method:?}: params did not change");
        assert_eq!(opt.t, 1.0);
        params = p_before; // reset for next method
    }
}

#[test]
fn imitation_converges_through_pjrt() {
    // repeated imitation steps on one fixed trajectory must reduce loss —
    // the end-to-end Stage-I signal through the full rust->PJRT path.
    let Some(nets) = nets_or_skip() else { return };
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let feats = static_features(&g, &topo, 1.0);
    let variant = nets.manifest.variant_for(g.n(), g.m()).unwrap().clone();
    let enc = GraphEncoding::build(&g, &feats, &nets.manifest, &variant).unwrap();
    let mut params = nets.init_params().unwrap();

    let cfg = EpisodeCfg {
        method: Method::Doppler,
        epsilon: 1.0, // random behavior: trajectory quality irrelevant here
        n_devices: 4,
        per_step_encode: false,
    };
    let mut rng = Rng::new(11);
    let ep = run_episode(&nets, &enc, &g, &topo, &feats, &params, &cfg, &mut rng).unwrap();

    let mut opt = OptState::new(params.len());
    let dev_mask = doppler::policy::device_mask(nets.manifest.max_devices, 4);
    let mut first = 0.0;
    let mut last = 0.0;
    for i in 0..60 {
        let (loss, _) = nets
            .train(Method::Doppler, &variant, &enc, &mut params, &mut opt,
                   &ep.trajectory, &dev_mask, 1.0, 5e-3, 0.0)
            .unwrap();
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(
        last < first * 0.92,
        "imitation loss did not drop: {first} -> {last} (note: symmetric shard nodes bound the CE floor)"
    );
}

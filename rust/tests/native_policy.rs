//! Native-backend integration: full ASSIGN episodes and train steps for
//! all three methods with zero artifacts, imitation convergence through
//! the analytic-gradient path, and a finite-difference check that the
//! implemented gradient is the gradient of the implemented loss.
//!
//! (Forward-pass numerics are pinned against the JAX reference
//! separately in tests/golden_logits.rs.)

use doppler::features::static_features;
use doppler::graph::workloads::{chainmm, Scale};
use doppler::policy::{
    run_episode, EpisodeCfg, GraphEncoding, Method, NativePolicy, OptState, PolicyBackend,
};
use doppler::sim::topology::DeviceTopology;
use doppler::util::rng::Rng;

struct Setup {
    nets: NativePolicy,
    g: doppler::graph::Graph,
    topo: DeviceTopology,
    feats: doppler::features::StaticFeatures,
    enc: GraphEncoding,
    params: Vec<f32>,
}

fn setup() -> Setup {
    let nets = NativePolicy::builtin();
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let feats = static_features(&g, &topo, 1.0);
    let variant = nets.variant_for_graph(g.n(), g.m()).unwrap();
    // exact-fit variant: no padding needed natively
    assert_eq!(variant.n, g.n());
    let enc = GraphEncoding::build(&g, &feats, nets.manifest(), &variant).unwrap();
    let params = PolicyBackend::init_params(&nets).unwrap();
    Setup { nets, g, topo, feats, enc, params }
}

#[test]
fn episode_and_train_roundtrip_all_methods() {
    let s = setup();
    let variant = s.nets.variant_for_graph(s.g.n(), s.g.m()).unwrap();

    // encode: finite and deterministic
    let hcat = s.nets.encode(&variant, &s.enc, &s.params).unwrap();
    assert_eq!(hcat.len(), s.enc.n * s.nets.manifest().sel_in);
    assert!(hcat.iter().all(|x| x.is_finite()));
    assert_eq!(hcat, s.nets.encode(&variant, &s.enc, &s.params).unwrap());

    for method in [Method::Doppler, Method::Placeto, Method::Gdp] {
        let cfg = EpisodeCfg {
            method,
            epsilon: 0.2,
            n_devices: 4,
            per_step_encode: false,
        };
        let mut rng = Rng::new(7);
        let mut params = s.params.clone();
        let ep = run_episode(&s.nets, &s.enc, &s.g, &s.topo, &s.feats, &params, &cfg, &mut rng)
            .unwrap();
        assert_eq!(ep.assignment.len(), s.g.n());
        assert!(ep.assignment.iter().all(|&d| d < 4));
        assert_eq!(ep.encode_calls, 1);
        let steps: f32 = ep.trajectory.step_mask.iter().sum();
        assert_eq!(steps as usize, s.g.n());
        // chosen action is always among candidates
        for h in 0..s.g.n() {
            let v = ep.trajectory.sel_actions[h] as usize;
            assert!(
                ep.trajectory.cand_masks[h * s.enc.n + v] > 0.0,
                "{method:?} step {h}: action not candidate"
            );
        }

        // train step: loss finite, entropy non-negative, params move
        let mut opt = OptState::new(params.len());
        let dev_mask = doppler::policy::device_mask(s.nets.manifest().max_devices, 4);
        let before = params.clone();
        let (loss, ent) = s
            .nets
            .train(
                method, &variant, &s.enc, &mut params, &mut opt, &ep.trajectory, &dev_mask, 1.0,
                1e-3, 1e-2,
            )
            .unwrap();
        assert!(loss.is_finite() && ent.is_finite(), "{method:?}: loss={loss} ent={ent}");
        assert!(ent >= 0.0);
        assert_ne!(params, before, "{method:?}: params did not change");
        assert_eq!(opt.t, 1.0);
    }
}

#[test]
fn per_step_encode_counts_encoder_calls() {
    let s = setup();
    let cfg = EpisodeCfg {
        method: Method::Doppler,
        epsilon: 0.0,
        n_devices: 4,
        per_step_encode: true,
    };
    let mut rng = Rng::new(3);
    let ep = run_episode(&s.nets, &s.enc, &s.g, &s.topo, &s.feats, &s.params, &cfg, &mut rng)
        .unwrap();
    assert_eq!(ep.encode_calls, s.g.n());
}

#[test]
fn imitation_converges_natively() {
    // repeated imitation steps on one fixed trajectory must reduce loss —
    // the end-to-end Stage-I signal through the analytic-gradient path.
    let s = setup();
    let variant = s.nets.variant_for_graph(s.g.n(), s.g.m()).unwrap();
    let cfg = EpisodeCfg {
        method: Method::Doppler,
        epsilon: 1.0, // random behavior: trajectory quality irrelevant here
        n_devices: 4,
        per_step_encode: false,
    };
    let mut rng = Rng::new(11);
    let mut params = s.params.clone();
    let ep = run_episode(&s.nets, &s.enc, &s.g, &s.topo, &s.feats, &params, &cfg, &mut rng)
        .unwrap();

    let mut opt = OptState::new(params.len());
    let dev_mask = doppler::policy::device_mask(s.nets.manifest().max_devices, 4);
    let mut first = 0.0;
    let mut last = 0.0;
    for i in 0..60 {
        let (loss, _) = s
            .nets
            .train(
                Method::Doppler, &variant, &s.enc, &mut params, &mut opt, &ep.trajectory,
                &dev_mask, 1.0, 5e-3, 0.0,
            )
            .unwrap();
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(
        last < first * 0.92,
        "imitation loss did not drop: {first} -> {last} (symmetric shard nodes bound the CE floor)"
    );
}

/// The analytic gradient must be the gradient of the implemented loss:
/// central finite differences along the gradient direction.
#[test]
fn gradient_matches_finite_difference() {
    let s = setup();
    let dev_mask = doppler::policy::device_mask(s.nets.manifest().max_devices, 4);

    for (method, seed) in [(Method::Doppler, 5u64), (Method::Placeto, 6), (Method::Gdp, 7)] {
        let cfg = EpisodeCfg {
            method,
            epsilon: 0.5,
            n_devices: 4,
            per_step_encode: false,
        };
        let mut rng = Rng::new(seed);
        let ep = run_episode(&s.nets, &s.enc, &s.g, &s.topo, &s.feats, &s.params, &cfg, &mut rng)
            .unwrap();
        let (adv, entw) = (0.7f32, 1e-2f32);
        let (_, _, grads) = s
            .nets
            .loss_and_grads(method, &s.enc, &s.params, &ep.trajectory, &dev_mask, adv, entw)
            .unwrap();

        // direction = normalized gradient (maximizes signal-to-noise in f32)
        let gnorm = (grads.iter().map(|g| (*g as f64).powi(2)).sum::<f64>()).sqrt();
        assert!(gnorm > 0.0, "{method:?}: zero gradient");
        let eps = 2e-3f32;
        let mut plus = s.params.clone();
        let mut minus = s.params.clone();
        for i in 0..plus.len() {
            let d = (grads[i] as f64 / gnorm) as f32;
            plus[i] += eps * d;
            minus[i] -= eps * d;
        }
        let (lp, _) = s
            .nets
            .episode_loss(method, &s.enc, &plus, &ep.trajectory, &dev_mask, adv, entw)
            .unwrap();
        let (lm, _) = s
            .nets
            .episode_loss(method, &s.enc, &minus, &ep.trajectory, &dev_mask, adv, entw)
            .unwrap();
        let fd = (lp as f64 - lm as f64) / (2.0 * eps as f64);
        // analytic directional derivative along the unit gradient = |g|
        let rel = (fd - gnorm).abs() / gnorm.max(1e-12);
        assert!(
            rel < 0.05,
            "{method:?}: finite-difference {fd:.6e} vs analytic {gnorm:.6e} (rel {rel:.3})"
        );
    }
}

/// Native episodes interoperate with padded encodings too (a PJRT-sized
/// variant): masks make padding inert.
#[test]
fn native_handles_padded_encodings() {
    let nets = NativePolicy::builtin();
    let g = chainmm(Scale::Tiny);
    let topo = DeviceTopology::p100x4();
    let feats = static_features(&g, &topo, 1.0);
    // pad like the PJRT n96 variant
    let variant = doppler::runtime::manifest::VariantInfo {
        n: 96,
        e: 224,
        artifacts: Default::default(),
    };
    let enc = GraphEncoding::build(&g, &feats, nets.manifest(), &variant).unwrap();
    let params = PolicyBackend::init_params(&nets).unwrap();
    let hcat = nets.encode(&variant, &enc, &params).unwrap();
    // padding rows must be exactly masked out
    let si = nets.manifest().sel_in;
    assert!(hcat[g.n() * si..].iter().all(|&x| x == 0.0), "padding region not masked");

    let cfg = EpisodeCfg {
        method: Method::Doppler,
        epsilon: 0.1,
        n_devices: 4,
        per_step_encode: false,
    };
    let mut rng = Rng::new(2);
    let ep = run_episode(&nets, &enc, &g, &topo, &feats, &params, &cfg, &mut rng).unwrap();
    assert_eq!(ep.assignment.len(), g.n());
}

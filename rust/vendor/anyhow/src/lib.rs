//! Minimal, API-compatible subset of the `anyhow` crate, vendored because
//! the offline build image cannot reach crates.io. Covers exactly what
//! this repository uses: [`Error`], [`Result`], the [`Context`] trait,
//! and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.
//!
//! Typed-error support: the blanket `From` conversion additionally stores
//! the original error value as an opaque payload, so callers can recover
//! it with [`Error::downcast_ref`] (used by the fault-tolerance layer to
//! match `RolloutError` / `Interrupted` through `anyhow::Result` plumbing).
//! Caveat vs real anyhow: the [`Context`] trait's `Result` impl re-renders
//! the source error as a string, so a `.context(...)` frame added through
//! that path DROPS the payload — match typed errors before adding context.
//! `Error::context` (the inherent method) keeps it.

use std::any::Any;
use std::fmt;

/// A string-backed error value with optional context frames.
pub struct Error {
    /// Context frames, outermost first, then the root message last.
    chain: Vec<String>,
    /// The original typed error (when built via the blanket `From`).
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
            payload: None,
        }
    }

    /// Wrap with an outer context frame (keeps any typed payload).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Borrow the original typed error, if this `Error` was produced by
    /// the blanket `From<E: std::error::Error>` conversion from a `T`.
    pub fn downcast_ref<T: 'static>(&self) -> Option<&T> {
        self.payload.as_ref()?.downcast_ref::<T>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, like anyhow's `{:#}`.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let msg = e.to_string();
        Error {
            chain: vec![msg],
            payload: Some(Box::new(e)),
        }
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        // `{:#}` preserves the full chain when E is itself an `Error`.
        self.map_err(|e| Error::msg(format!("{e:#}")).context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::Error::msg(format!($($arg)*)))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        let rendered = format!("{e:#}");
        assert!(rendered.starts_with("reading config: "), "{rendered}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad {} at {}", "token", 3);
        assert_eq!(format!("{e}"), "bad token at 3");

        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert!(f(3).is_ok());
        assert!(f(5).is_err());
        assert!(f(50).is_err());
    }

    #[test]
    fn alternate_display_shows_chain() {
        let e = Error::msg("root cause").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause");
    }

    #[derive(Debug)]
    struct Typed {
        code: u32,
    }
    impl fmt::Display for Typed {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "typed error {}", self.code)
        }
    }
    impl std::error::Error for Typed {}

    #[test]
    fn downcast_recovers_typed_payload() {
        fn fail() -> Result<()> {
            Err(Typed { code: 7 })?;
            Ok(())
        }
        let e = fail().unwrap_err();
        assert_eq!(format!("{e}"), "typed error 7");
        assert_eq!(e.downcast_ref::<Typed>().map(|t| t.code), Some(7));
        assert!(e.downcast_ref::<String>().is_none());

        // The inherent context method keeps the payload...
        let e = e.context("outer");
        assert_eq!(e.downcast_ref::<Typed>().map(|t| t.code), Some(7));

        // ...but Error::msg never has one.
        assert!(Error::msg("plain").downcast_ref::<Typed>().is_none());
    }

    #[test]
    fn context_trait_drops_payload_documented() {
        // Known shim limitation: the blanket `Context` impl stringifies the
        // source, so the typed payload does not survive `.context()` on a
        // Result. This test pins the documented behavior.
        let r: std::result::Result<(), Typed> = Err(Typed { code: 9 });
        let e = r.context("while frobbing").unwrap_err();
        assert_eq!(format!("{e:#}"), "while frobbing: typed error 9");
        assert!(e.downcast_ref::<Typed>().is_none());
    }
}

//! Compile-time stub for the `xla` (xla_extension / PJRT) bindings.
//!
//! The offline build image ships neither the `xla` crate nor
//! libxla_extension, so this vendored crate provides the exact API surface
//! `doppler::runtime` and `doppler::policy::nets` compile against, with
//! [`PjRtClient::cpu`] returning an error at run time. Everything that
//! needs the policy networks (`PolicyNets::load*`) therefore fails with a
//! clear message and the callers skip gracefully — the simulator,
//! engine, heuristics, rollout, and trainer plumbing stay fully testable.
//!
//! Dropping a real `xla` crate (with libxla_extension) in place of this
//! stub re-enables the PJRT path without touching `doppler` itself; the
//! host types and [`Literal`] layout match xla_extension 0.5.x.

#![allow(dead_code)]

use std::fmt;

/// Error type for every fallible stub operation.
#[derive(Debug, Clone)]
pub struct XlaError {
    msg: String,
}

impl XlaError {
    pub fn new<M: fmt::Display>(msg: M) -> XlaError {
        XlaError {
            msg: msg.to_string(),
        }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

const STUB_MSG: &str = "PJRT runtime unavailable: this build uses the vendored xla stub \
     (no libxla_extension in the offline image); policy-network paths are disabled";

/// Element types a [`Literal`] can hold (only what doppler exchanges).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Sealed-ish helper for the generic `Literal::vec1` / `Literal::to_vec`.
pub trait NativeType: Copy + Sized {
    const TY: ElementType;
    fn to_bits_vec(xs: &[Self]) -> Vec<u8>;
    fn from_bits(bytes: &[u8]) -> Vec<Self>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn to_bits_vec(xs: &[Self]) -> Vec<u8> {
        xs.iter().flat_map(|x| x.to_le_bytes()).collect()
    }
    fn from_bits(bytes: &[u8]) -> Vec<Self> {
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn to_bits_vec(xs: &[Self]) -> Vec<u8> {
        xs.iter().flat_map(|x| x.to_le_bytes()).collect()
    }
    fn from_bits(bytes: &[u8]) -> Vec<Self> {
        bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

/// A host tensor (or tuple of tensors): the literal interchange type.
#[derive(Clone, Debug)]
pub enum Literal {
    Tensor {
        ty: ElementType,
        dims: Vec<i64>,
        bytes: Vec<u8>,
    },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a flat host slice.
    pub fn vec1<T: NativeType>(xs: &[T]) -> Literal {
        Literal::Tensor {
            ty: T::TY,
            dims: vec![xs.len() as i64],
            bytes: T::to_bits_vec(xs),
        }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Tensor { ty, bytes, .. } => {
                let want: i64 = dims.iter().product();
                let have = (bytes.len() / 4) as i64;
                if want != have {
                    return Err(XlaError::new(format!(
                        "reshape: {have} elements into dims {dims:?}"
                    )));
                }
                Ok(Literal::Tensor {
                    ty: *ty,
                    dims: dims.to_vec(),
                    bytes: bytes.clone(),
                })
            }
            Literal::Tuple(_) => Err(XlaError::new("reshape on tuple literal")),
        }
    }

    /// Flat host vector copy-out.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Tensor { ty, bytes, .. } => {
                if *ty != T::TY {
                    return Err(XlaError::new("to_vec: element type mismatch"));
                }
                Ok(T::from_bits(bytes))
            }
            Literal::Tuple(_) => Err(XlaError::new("to_vec on tuple literal")),
        }
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(xs) => Ok(xs),
            lit => Ok(vec![lit]),
        }
    }
}

/// Parsed HLO module (stub: retains nothing).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Parse HLO text from a file. The stub only checks readability; the
    /// failure point for stub builds is [`PjRtClient::cpu`].
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::read_to_string(path)
            .map_err(|e| XlaError::new(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { _priv: () })
    }
}

/// An XLA computation handle (stub).
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client (stub: construction always fails, gating all callers).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::new(STUB_MSG))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::new(STUB_MSG))
    }
}

/// Compiled executable (stub: unreachable — the client cannot be built).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<A: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[A],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new(STUB_MSG))
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::new(STUB_MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn literal_roundtrip_i32() {
        let l = Literal::vec1(&[-1i32, 7]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![-1, 7]);
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn client_is_gated() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
